//! Flight recorder: zero-alloc per-stage round tracing.
//!
//! PHub's design came from stage-by-stage measurement (the paper's §2
//! characterization splits a round into network, copy, aggregation, and
//! optimization time before proposing a fix for each). This module gives
//! the reproduction the same instrument: every thread that touches a
//! round records timestamped span events into its own fixed-capacity
//! ring buffer at the existing stage boundaries — frame read, ring
//! enqueue/dequeue, absorb, fused mean+optimize, reply encode, socket
//! write — plus recovery events (rollback, deadline trip, residual
//! commit). A drained recording renders directly as a chrome://tracing
//! timeline ([`chrome_trace_json`]), reproducing the paper's per-stage
//! breakdown from live rounds.
//!
//! # Recording cost and the exact-zero invariant
//!
//! The recorder is on the hottest paths in the tree, so it obeys the same
//! discipline they do (`rust/tests/alloc_discipline.rs` runs with tracing
//! compiled in *and* enabled):
//!
//! * **Preallocated slots.** Each recording thread owns one
//!   [`TraceRing`] of [`RING_CAPACITY`] fixed slots, allocated once the
//!   first time the thread records (warm-up, like the kernel-tier
//!   resolve) and never resized. New events overwrite the oldest.
//! * **Atomics only.** A record is one monotonic-clock read plus a
//!   handful of relaxed atomic stores under a per-slot seqlock stamp
//!   (odd = write in progress); readers validate the stamp and retry, so
//!   a concurrent scrape can never observe a torn event and never makes
//!   a writer wait. No mutex, no CAS loop, no allocation.
//! * **Branch-out when off.** The per-server runtime toggle
//!   ([`set_enabled`]) reduces every hook to one relaxed load and a
//!   branch; compiling without the `trace` cargo feature (on by
//!   default) reduces them to nothing.
//!
//! The thread table holds up to [`MAX_RINGS`] rings for the life of the
//! process; threads beyond that record nothing (recording is
//! best-effort diagnostics, never load-bearing). Ring indices double as
//! chrome-tracing `tid`s.

use std::fmt;

/// A round stage (or recovery event) a span is attributed to. The
/// numbering is part of the recorded event, not a wire format — it may
/// be extended but existing values should keep their meaning within a
/// release.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Leader: blocking read of one wire frame off a worker socket
    /// (includes the wait for the worker — inter-round idle shows up
    /// here, which is exactly the "network + straggler wait" band of
    /// the paper's breakdown).
    FrameRead = 0,
    /// Producer side of a (worker, core) request ring: enqueue of one
    /// push message (includes backpressure wait on a full ring).
    RingEnqueue = 1,
    /// Core side: a push message left its request ring (instant).
    RingDequeue = 2,
    /// Engine: tall-aggregation absorb of one gradient chunk.
    Absorb = 3,
    /// Engine: the fused mean+optimizer pass on a chunk's last arrival.
    Optimize = 4,
    /// Leader: serializing one reply chunk into the connection's
    /// staging buffer.
    ReplyEncode = 5,
    /// Leader: writing + flushing the staged replies to the socket.
    SocketWrite = 6,
    /// Recovery: a shard applied an epoch rollback (instant).
    Rollback = 7,
    /// Recovery: a round deadline declared a stalled worker dead
    /// (instant).
    DeadlineTrip = 8,
    /// Recovery: staged residual checkpoints committed at a round
    /// boundary (instant).
    ResidualCommit = 9,
}

/// Every stage, for iteration (breakdown tables, tests).
pub const ALL_STAGES: [Stage; 10] = [
    Stage::FrameRead,
    Stage::RingEnqueue,
    Stage::RingDequeue,
    Stage::Absorb,
    Stage::Optimize,
    Stage::ReplyEncode,
    Stage::SocketWrite,
    Stage::Rollback,
    Stage::DeadlineTrip,
    Stage::ResidualCommit,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::FrameRead => "frame_read",
            Stage::RingEnqueue => "ring_enqueue",
            Stage::RingDequeue => "ring_dequeue",
            Stage::Absorb => "absorb",
            Stage::Optimize => "optimize",
            Stage::ReplyEncode => "reply_encode",
            Stage::SocketWrite => "socket_write",
            Stage::Rollback => "rollback",
            Stage::DeadlineTrip => "deadline_trip",
            Stage::ResidualCommit => "residual_commit",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        ALL_STAGES.get(v as usize).copied()
    }
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One recorded span, as read back by a scrape. `ts_ns` is nanoseconds
/// since the process's first recorded event; `dur_ns` is 0 for instant
/// events; `tid` is the recording thread's ring index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub stage: Stage,
    pub job: u32,
    pub chunk: u32,
    pub worker: u32,
    pub tid: u32,
}

/// Render events as chrome://tracing "trace event format" JSON (complete
/// duration events, microsecond timestamps). Load the output in
/// `chrome://tracing` or Perfetto to see the per-stage round timeline.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(64 + events.len() * 96);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"phub\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
             \"pid\":1,\"tid\":{},\"args\":{{\"job\":{},\"chunk\":{},\"worker\":{}}}}}",
            e.stage.name(),
            e.ts_ns as f64 / 1000.0,
            e.dur_ns as f64 / 1000.0,
            e.tid,
            e.job,
            e.chunk,
            e.worker,
        );
    }
    out.push_str("]}");
    out
}

/// Slots per thread ring. At ~10 events per chunk round a 4-chunk job
/// keeps its last ~100 rounds in flight-recorder range.
pub const RING_CAPACITY: usize = 4096;

/// Threads the process-wide ring table can hold; later threads record
/// nothing (best-effort).
pub const MAX_RINGS: usize = 64;

#[cfg(feature = "trace")]
mod imp {
    use super::{Stage, TraceEvent, MAX_RINGS, RING_CAPACITY};
    use std::cell::Cell;
    use std::ptr;
    use std::sync::atomic::{
        fence, AtomicBool, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };
    use std::sync::OnceLock;
    use std::time::Instant;

    /// One event slot. Every field is a relaxed atomic under the `seq`
    /// stamp (crossbeam-style seqlock: odd while a write is in
    /// progress), so readers can snapshot concurrently without ever
    /// observing a torn event and without making the writer wait.
    #[derive(Default)]
    struct Slot {
        seq: AtomicU32,
        stage: AtomicU32,
        job: AtomicU32,
        chunk: AtomicU32,
        worker: AtomicU32,
        ts_ns: AtomicU64,
        dur_ns: AtomicU64,
    }

    /// A fixed-capacity single-writer/multi-reader event ring. The
    /// global table owns one per recording thread; standalone instances
    /// exist only in tests.
    pub struct TraceRing {
        slots: Box<[Slot]>,
        /// Monotone count of events ever written; the write cursor is
        /// `head % capacity`. Advanced *after* the slot write completes
        /// so readers only walk fully-written indices.
        head: AtomicU64,
    }

    impl TraceRing {
        pub fn with_capacity(cap: usize) -> TraceRing {
            let slots: Vec<Slot> = (0..cap.max(1)).map(|_| Slot::default()).collect();
            TraceRing {
                slots: slots.into_boxed_slice(),
                head: AtomicU64::new(0),
            }
        }

        /// Record one event, overwriting the oldest when full. Single
        /// writer: only the owning thread calls this.
        pub fn record(
            &self,
            stage: Stage,
            job: u32,
            chunk: u32,
            worker: u32,
            ts_ns: u64,
            dur_ns: u64,
        ) {
            let h = self.head.load(Ordering::Relaxed);
            let slot = &self.slots[(h % self.slots.len() as u64) as usize];
            let s = slot.seq.load(Ordering::Relaxed);
            slot.seq.store(s.wrapping_add(1), Ordering::Relaxed); // odd: in progress
            fence(Ordering::Release);
            slot.stage.store(stage as u32, Ordering::Relaxed);
            slot.job.store(job, Ordering::Relaxed);
            slot.chunk.store(chunk, Ordering::Relaxed);
            slot.worker.store(worker, Ordering::Relaxed);
            slot.ts_ns.store(ts_ns, Ordering::Relaxed);
            slot.dur_ns.store(dur_ns, Ordering::Relaxed);
            slot.seq.store(s.wrapping_add(2), Ordering::Release);
            self.head.store(h + 1, Ordering::Release);
        }

        /// Number of events ever recorded (not capped at capacity).
        pub fn recorded(&self) -> u64 {
            self.head.load(Ordering::Acquire)
        }

        /// Append the ring's current events (oldest retained first) to
        /// `out`, optionally filtered to one job. Slots a writer is
        /// overwriting mid-read are retried a few times and then
        /// skipped — a scrape never yields a torn event and never
        /// blocks the writer.
        pub fn snapshot_into(&self, tid: u32, job_filter: Option<u32>, out: &mut Vec<TraceEvent>) {
            let head = self.head.load(Ordering::Acquire);
            let cap = self.slots.len() as u64;
            let start = head.saturating_sub(cap);
            for i in start..head {
                let slot = &self.slots[(i % cap) as usize];
                for _attempt in 0..4 {
                    let s1 = slot.seq.load(Ordering::Acquire);
                    if s1 & 1 == 1 {
                        std::hint::spin_loop();
                        continue; // write in progress
                    }
                    let ev = TraceEvent {
                        ts_ns: slot.ts_ns.load(Ordering::Relaxed),
                        dur_ns: slot.dur_ns.load(Ordering::Relaxed),
                        stage: match Stage::from_u8(slot.stage.load(Ordering::Relaxed) as u8) {
                            Some(s) => s,
                            None => break,
                        },
                        job: slot.job.load(Ordering::Relaxed),
                        chunk: slot.chunk.load(Ordering::Relaxed),
                        worker: slot.worker.load(Ordering::Relaxed),
                        tid,
                    };
                    fence(Ordering::Acquire);
                    if slot.seq.load(Ordering::Relaxed) != s1 {
                        continue; // overwritten mid-read; retry
                    }
                    if job_filter.is_none_or(|j| j == ev.job) {
                        out.push(ev);
                    }
                    break;
                }
            }
        }
    }

    /// Runtime toggle (process-wide; `PHubServer::set_tracing` flips it).
    static ENABLED: AtomicBool = AtomicBool::new(true);
    /// Next free index in the ring table.
    static NEXT_RING: AtomicUsize = AtomicUsize::new(0);
    /// The process-wide ring table: one lazily-allocated ring per
    /// recording thread, alive for the life of the process so scrapes
    /// can read rings of exited threads.
    static RINGS: [AtomicPtr<TraceRing>; MAX_RINGS] =
        [const { AtomicPtr::new(ptr::null_mut()) }; MAX_RINGS];

    thread_local! {
        /// This thread's ring-table index: -1 unclaimed, -2 table full.
        static MY_RING: Cell<isize> = const { Cell::new(-1) };
    }

    /// Nanoseconds since the first call (the process trace epoch).
    /// Always at least 1, so a 0 span-start can mean "tracing was off".
    fn now_ns() -> u64 {
        static BASE: OnceLock<Instant> = OnceLock::new();
        (BASE.get_or_init(Instant::now).elapsed().as_nanos() as u64).max(1)
    }

    /// The calling thread's ring, claiming a table slot (and allocating
    /// the ring — the one warm-up-time allocation) on first use.
    fn my_ring() -> Option<&'static TraceRing> {
        MY_RING.with(|cell| {
            let i = cell.get();
            if i >= 0 {
                // SAFETY: a claimed index always holds a ring pointer that
                // lives for the rest of the process.
                return Some(unsafe { &*RINGS[i as usize].load(Ordering::Relaxed) });
            }
            if i == -2 {
                return None;
            }
            let idx = NEXT_RING.fetch_add(1, Ordering::Relaxed);
            if idx >= MAX_RINGS {
                cell.set(-2);
                return None;
            }
            let ring = Box::into_raw(Box::new(TraceRing::with_capacity(RING_CAPACITY)));
            RINGS[idx].store(ring, Ordering::Release);
            cell.set(idx as isize);
            // SAFETY: just stored; intentionally process-lifetime.
            Some(unsafe { &*ring })
        })
    }

    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    #[inline]
    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn start() -> u64 {
        if enabled() {
            now_ns()
        } else {
            0
        }
    }

    #[inline]
    pub fn span(stage: Stage, job: u32, chunk: u32, worker: u32, start_ns: u64) {
        if start_ns == 0 || !enabled() {
            return;
        }
        let now = now_ns();
        if let Some(ring) = my_ring() {
            ring.record(stage, job, chunk, worker, start_ns, now.saturating_sub(start_ns));
        }
    }

    #[inline]
    pub fn instant(stage: Stage, job: u32, chunk: u32, worker: u32) {
        if !enabled() {
            return;
        }
        let now = now_ns();
        if let Some(ring) = my_ring() {
            ring.record(stage, job, chunk, worker, now, 0);
        }
    }

    /// Snapshot every thread ring, optionally filtered to one job.
    /// Events are grouped by ring (thread), oldest-first within each.
    pub fn snapshot_filtered(job: Option<u32>) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        let n = NEXT_RING.load(Ordering::Acquire).min(MAX_RINGS);
        for (tid, cell) in RINGS.iter().enumerate().take(n) {
            let p = cell.load(Ordering::Acquire);
            if p.is_null() {
                continue; // claimed but not yet published
            }
            // SAFETY: published ring pointers live for the process.
            unsafe { &*p }.snapshot_into(tid as u32, job, &mut out);
        }
        out
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::sync::Arc;

        #[test]
        fn wraparound_evicts_oldest_never_tears() {
            let ring = TraceRing::with_capacity(8);
            for i in 0..20u64 {
                ring.record(Stage::Absorb, i as u32, i as u32, i as u32, i + 1, i);
            }
            assert_eq!(ring.recorded(), 20);
            let mut out = Vec::new();
            ring.snapshot_into(0, None, &mut out);
            // Exactly the 8 newest events, oldest retained first.
            assert_eq!(out.len(), 8);
            for (k, ev) in out.iter().enumerate() {
                let i = 12 + k as u64;
                assert_eq!(ev.ts_ns, i + 1);
                assert_eq!(ev.dur_ns, i);
                assert_eq!(ev.job as u64, i);
                assert_eq!(ev.chunk as u64, i);
                assert_eq!(ev.worker as u64, i);
            }
        }

        #[test]
        fn job_filter_selects_only_that_job() {
            let ring = TraceRing::with_capacity(16);
            for i in 0..10u32 {
                ring.record(Stage::FrameRead, i % 2, i, 0, 1 + i as u64, 1);
            }
            let mut out = Vec::new();
            ring.snapshot_into(0, Some(1), &mut out);
            assert_eq!(out.len(), 5);
            assert!(out.iter().all(|e| e.job == 1));
        }

        /// Concurrent scrapes of a live writer never observe a torn
        /// event: every field of every yielded event belongs to the
        /// same write (the writer keeps job == chunk == worker and
        /// dur == ts - 1 as the consistency witness).
        #[test]
        fn concurrent_snapshot_is_never_torn() {
            let ring = Arc::new(TraceRing::with_capacity(4));
            let w = {
                let ring = ring.clone();
                std::thread::spawn(move || {
                    for i in 1..=20_000u64 {
                        let v = (i % 1000) as u32;
                        ring.record(Stage::Optimize, v, v, v, i, i - 1);
                    }
                })
            };
            let mut seen = 0usize;
            let mut out = Vec::new();
            while !w.is_finished() {
                out.clear();
                ring.snapshot_into(0, None, &mut out);
                for ev in &out {
                    assert_eq!(ev.job, ev.chunk, "torn event: {ev:?}");
                    assert_eq!(ev.job, ev.worker, "torn event: {ev:?}");
                    assert_eq!(ev.dur_ns, ev.ts_ns - 1, "torn event: {ev:?}");
                    seen += 1;
                }
            }
            w.join().unwrap();
            out.clear();
            ring.snapshot_into(0, None, &mut out);
            assert_eq!(out.len(), 4, "full ring snapshots at capacity");
            assert!(seen > 0 || out.len() == 4);
        }

        #[test]
        fn global_record_and_snapshot_round_trip() {
            // Best-effort: the table may already be full from other
            // tests' threads, in which case span() is a silent no-op.
            set_enabled(true);
            let t = start();
            assert!(t > 0);
            span(Stage::ReplyEncode, 7_000_001, 3, 2, t);
            let got = snapshot_filtered(Some(7_000_001));
            if my_ring().is_some() {
                assert_eq!(got.len(), 1);
                assert_eq!(got[0].stage, Stage::ReplyEncode);
                assert_eq!((got[0].chunk, got[0].worker), (3, 2));
            }
            // Disabled: start() hands out 0 and span() drops it.
            set_enabled(false);
            assert_eq!(start(), 0);
            span(Stage::ReplyEncode, 7_000_001, 4, 2, t);
            let after = snapshot_filtered(Some(7_000_001));
            assert_eq!(after.len(), got.len());
            set_enabled(true);
        }
    }
}

#[cfg(feature = "trace")]
pub use imp::{enabled, instant, set_enabled, snapshot_filtered, span, start, TraceRing};

/// Snapshot every thread ring (all jobs).
#[cfg(feature = "trace")]
pub fn snapshot() -> Vec<TraceEvent> {
    imp::snapshot_filtered(None)
}

// ---- `trace` feature disabled: every hook compiles to nothing. ----

#[cfg(not(feature = "trace"))]
pub fn set_enabled(_on: bool) {}

#[cfg(not(feature = "trace"))]
#[inline]
pub fn enabled() -> bool {
    false
}

#[cfg(not(feature = "trace"))]
#[inline]
pub fn start() -> u64 {
    0
}

#[cfg(not(feature = "trace"))]
#[inline]
pub fn span(_stage: Stage, _job: u32, _chunk: u32, _worker: u32, _start_ns: u64) {}

#[cfg(not(feature = "trace"))]
#[inline]
pub fn instant(_stage: Stage, _job: u32, _chunk: u32, _worker: u32) {}

#[cfg(not(feature = "trace"))]
pub fn snapshot_filtered(_job: Option<u32>) -> Vec<TraceEvent> {
    Vec::new()
}

#[cfg(not(feature = "trace"))]
pub fn snapshot() -> Vec<TraceEvent> {
    Vec::new()
}

#[cfg(test)]
mod format_tests {
    use super::*;

    #[test]
    fn chrome_json_is_well_formed() {
        let events = [
            TraceEvent {
                ts_ns: 1500,
                dur_ns: 250,
                stage: Stage::Absorb,
                job: 1,
                chunk: 2,
                worker: 0,
                tid: 3,
            },
            TraceEvent {
                ts_ns: 2000,
                dur_ns: 0,
                stage: Stage::Rollback,
                job: 1,
                chunk: 0,
                worker: 0,
                tid: 3,
            },
        ];
        let json = chrome_trace_json(&events);
        let parsed = crate::jsonlite::parse(&json).expect("valid JSON");
        let evs = parsed
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        assert_eq!(evs.len(), 2);
        assert_eq!(
            evs[0].get("name").and_then(|v| v.as_str()),
            Some("absorb")
        );
        assert_eq!(evs[0].get("ts").and_then(|v| v.as_f64()), Some(1.5));
        assert!(chrome_trace_json(&[]).contains("\"traceEvents\":[]"));
    }

    #[test]
    fn stage_names_round_trip() {
        for (i, s) in ALL_STAGES.iter().enumerate() {
            assert_eq!(Stage::from_u8(i as u8), Some(*s));
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_u8(200), None);
    }
}
