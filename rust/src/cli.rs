//! Minimal CLI argument parsing (no clap in the offline environment).
//!
//! Supports `subcommand --flag value --flag=value --switch` forms and typed
//! accessors with defaults.

use std::collections::HashMap;

/// Parsed command line: a subcommand plus flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(stripped.to_string());
                }
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("sim --dnn RN50 --workers=8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("sim"));
        assert_eq!(a.get("dnn"), Some("RN50"));
        assert_eq!(a.get_usize("workers", 1), 8);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("net", "56g"), "56g");
        assert_eq!(a.get_usize("iters", 3), 3);
        assert_eq!(a.get_f64("lr", 0.1), 0.1);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--dnn AN");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get("dnn"), Some("AN"));
    }

    #[test]
    fn equals_and_space_forms_equivalent() {
        let a = parse("x --k=v");
        let b = parse("x --k v");
        assert_eq!(a.get("k"), b.get("k"));
    }
}
