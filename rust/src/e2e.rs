//! End-to-end live training: AOT-compiled JAX transformer (L2/L1) executed
//! via PJRT, gradients exchanged through the real PHub server (L3).
//!
//! This is the crate's existence proof that all three layers compose: the
//! worker compute is the `grad_step.hlo.txt` artifact, the PS is the
//! threaded PHub coordinator running the same Nesterov update as the
//! Pallas kernel, and the loss curve on a synthetic corpus goes down.
//! `examples/train_e2e.rs` and `phub train` both drive this module; the
//! recorded run lives in EXPERIMENTS.md.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::coordinator::{ConnectionManager, KeyTable, NesterovSgd, PHubServer};
use crate::coordinator::server::ServerConfig;
use crate::prop::Rng;
use crate::runtime::{self, Runtime};

/// Synthetic corpus: a noisy arithmetic token progression. Learnable by a
/// small causal LM (next ≈ prev + stride mod vocab), with 10% uniform
/// noise so loss does not collapse to zero.
pub fn synth_tokens(rng: &mut Rng, batch: usize, seq_plus1: usize, vocab: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(batch * seq_plus1);
    for _ in 0..batch {
        let mut t = rng.usize_in(0, vocab);
        let stride = 1 + rng.usize_in(0, 3);
        for _ in 0..seq_plus1 {
            out.push(t as i32);
            t = if rng.f64() < 0.1 {
                rng.usize_in(0, vocab)
            } else {
                (t + stride) % vocab
            };
        }
    }
    out
}

/// Result of a live training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub workers: usize,
    pub param_count: usize,
    /// Mean worker loss per step.
    pub losses: Vec<f32>,
    pub samples_per_sec: f64,
    pub exchanges_per_sec: f64,
}

impl TrainReport {
    /// Smoothed loss over the first/last `k` steps (for convergence checks).
    pub fn mean_loss_head_tail(&self, k: usize) -> (f32, f32) {
        let k = k.min(self.losses.len());
        let head = self.losses[..k].iter().sum::<f32>() / k as f32;
        let tail = self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32;
        (head, tail)
    }
}

/// Run live data-parallel training for `steps` iterations with `workers`
/// workers against a PHub server with `cores` aggregation threads.
///
/// Worker gradient computation executes the AOT artifact via PJRT on this
/// thread (one PJRT client; data-parallel semantics are preserved because
/// each worker gets its own minibatch and its own push). The exchange runs
/// on real server threads.
pub fn train(
    artifacts: &Path,
    workers: usize,
    steps: usize,
    cores: usize,
    lr: f32,
    momentum: f32,
    verbose: bool,
) -> Result<TrainReport> {
    let rt = Runtime::cpu(artifacts)?;
    let man = rt.manifest()?;
    let grad_step = rt.load("grad_step")?;
    let init = rt.initial_params()?;
    anyhow::ensure!(init.len() == man.padded_size, "params_init length");

    // PS setup via the paper's service API.
    let server = PHubServer::start(ServerConfig::cores(cores));
    let cm = ConnectionManager::new(server.clone());
    let svc = cm.create_service("e2e", workers).expect("namespace");
    let keys: Vec<(String, usize)> = man.keys.iter().map(|(n, _, l)| (n.clone(), *l)).collect();
    let table = KeyTable::from_manifest_keys(&keys, man.padded_size, man.chunk_elems);
    cm.init_service(
        &svc,
        table,
        &init,
        Arc::new(NesterovSgd { lr, momentum }),
    )
    .expect("init service");
    let mut handles: Vec<_> = (0..workers)
        .map(|w| cm.connect_service(&svc, w).expect("connect"))
        .collect();

    let mut params = init;
    let mut rng = Rng::new(0x5EED);
    let mut losses = Vec::with_capacity(steps);
    let start = Instant::now();

    for step in 0..steps {
        // Compute each worker's gradient with the PJRT artifact.
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(workers);
        let mut step_loss = 0.0f32;
        for _w in 0..workers {
            let toks = synth_tokens(&mut rng, man.batch, man.seq_len + 1, man.vocab);
            let p = runtime::literal_f32(&params, &[man.padded_size as i64])?;
            let t = runtime::literal_i32(&toks, &[man.batch as i64, (man.seq_len + 1) as i64])?;
            let out = grad_step.call(&[p, t])?;
            anyhow::ensure!(out.len() == 2, "grad_step returns (loss, grads)");
            step_loss += runtime::to_scalar_f32(&out[0])?;
            grads.push(runtime::to_vec_f32(&out[1])?);
        }
        step_loss /= workers as f32;
        losses.push(step_loss);

        // Exchange through the live server: workers push concurrently.
        let updated: Vec<Vec<f32>> = std::thread::scope(|s| {
            let joins: Vec<_> = handles
                .iter_mut()
                .zip(grads.iter())
                .map(|(h, g)| s.spawn(move || h.push_pull(g)))
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // Synchronous training invariant: all workers agree bit-for-bit.
        for u in &updated[1..] {
            anyhow::ensure!(u == &updated[0], "workers diverged at step {step}");
        }
        params = updated.into_iter().next().unwrap();

        if verbose && (step % 10 == 0 || step + 1 == steps) {
            println!("step {step:>4}  loss {step_loss:.4}");
        }
    }

    let elapsed = start.elapsed().as_secs_f64();
    PHubServer::shutdown(server);
    Ok(TrainReport {
        steps,
        workers,
        param_count: man.param_count,
        samples_per_sec: (steps * workers * man.batch) as f64 / elapsed,
        exchanges_per_sec: steps as f64 / elapsed,
        losses,
    })
}

/// `phub train` CLI front end.
pub fn train_cli(a: &Args) -> Result<()> {
    let artifacts = runtime::default_artifacts_dir();
    let workers = a.get_usize("workers", 4);
    let steps = a.get_usize("steps", 50);
    let cores = a.get_usize("cores", 4);
    let lr = a.get_f64("lr", 0.05) as f32;
    let mu = a.get_f64("momentum", 0.9) as f32;
    let r = train(
        artifacts.as_path(),
        workers,
        steps,
        cores,
        lr,
        mu,
        !a.has("quiet"),
    )
    .context("live training")?;
    let (head, tail) = r.mean_loss_head_tail(5);
    println!(
        "\ntrained {} params, {} steps x {} workers: loss {head:.3} -> {tail:.3}, \
         {:.1} samples/s, {:.2} exchanges/s",
        r.param_count, r.steps, r.workers, r.samples_per_sec, r.exchanges_per_sec
    );
    Ok(())
}
