//! InfiniBand queue-pair state-cache model (paper section 4.6).
//!
//! Queue pairs have on-NIC cached state; when the working set of active QPs
//! exceeds the cache, per-message handling slows down. The paper's Figure
//! 16 (right) shows that *fewer* QPs per connection win: extra QPs reduce
//! head-of-line blocking slightly but thrash the cache. We model the cache
//! as ideal-LRU over a uniformly-accessed QP population, which yields a
//! simple closed-form miss rate.

/// Per-NIC queue-pair cache.
#[derive(Debug, Clone)]
pub struct QpCache {
    /// Cache capacity in QP entries.
    pub entries: usize,
    /// Extra latency per message on a miss, seconds.
    pub miss_penalty: f64,
}

impl QpCache {
    pub fn new(entries: usize, miss_penalty: f64) -> Self {
        QpCache {
            entries,
            miss_penalty,
        }
    }

    /// Miss rate when `active_qps` are accessed uniformly.
    ///
    /// Ideal LRU over a uniform reference stream: if the population fits,
    /// no misses; otherwise each access hits with probability
    /// `entries / active_qps`.
    pub fn miss_rate(&self, active_qps: usize) -> f64 {
        if active_qps <= self.entries || active_qps == 0 {
            0.0
        } else {
            1.0 - self.entries as f64 / active_qps as f64
        }
    }

    /// Expected extra per-message latency given the active QP population.
    pub fn message_overhead(&self, active_qps: usize) -> f64 {
        self.miss_rate(active_qps) * self.miss_penalty
    }
}

/// Number of QPs a PS-side NIC must keep active: one per (worker,
/// connection) times the configured QPs per connection.
pub fn active_qps(n_workers: usize, qps_per_connection: usize) -> usize {
    n_workers * qps_per_connection
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_in_cache_no_misses() {
        let c = QpCache::new(64, 1e-6);
        assert_eq!(c.miss_rate(64), 0.0);
        assert_eq!(c.miss_rate(8), 0.0);
        assert_eq!(c.message_overhead(64), 0.0);
    }

    #[test]
    fn overflow_misses_scale() {
        let c = QpCache::new(64, 1e-6);
        let m128 = c.miss_rate(128);
        assert!((m128 - 0.5).abs() < 1e-9);
        let m256 = c.miss_rate(256);
        assert!((m256 - 0.75).abs() < 1e-9);
        assert!(c.message_overhead(256) > c.message_overhead(128));
    }

    #[test]
    fn more_qps_per_connection_more_pressure() {
        // 8 workers, sweep QPs/connection: the Fig 16 (right) tradeoff
        // direction — beyond the cache size, overhead grows monotonically.
        let c = QpCache::new(64, 1e-6);
        let mut prev = -1.0;
        for q in [1usize, 2, 4, 8, 16, 32, 64] {
            let o = c.message_overhead(active_qps(8, q));
            assert!(o >= prev);
            prev = o;
        }
    }

    #[test]
    fn zero_active_qps() {
        let c = QpCache::new(64, 1e-6);
        assert_eq!(c.miss_rate(0), 0.0);
    }
}
