//! Directed link: the unit of bandwidth in the fabric model.

/// Identifier for a link within a [`super::Fabric`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub usize);

/// A directed link with fixed capacity.
///
/// Links model every bandwidth-constrained stage the paper reasons about:
/// worker NIC tx/rx, each of PBox's 10 NIC ports, the ToR uplink under
/// oversubscription, and the PBox PCIe-to-memory bridge (section 4.7 shows
/// the bridge, not the NICs or DRAM, is the real ceiling — we model it as
/// one more link every PBox flow must traverse).
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Capacity in bytes/s.
    pub capacity: f64,
}

impl Link {
    pub fn new(name: impl Into<String>, capacity: f64) -> Self {
        Link {
            name: name.into(),
            capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_construction() {
        let l = Link::new("tor-up", 7e9);
        assert_eq!(l.name, "tor-up");
        assert!((l.capacity - 7e9).abs() < 1.0);
    }
}
