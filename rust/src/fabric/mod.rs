//! Flow-level network fabric simulator.
//!
//! Substitute for the paper's physical testbed (56/10 Gbps InfiniBand,
//! ToR switch, oversubscribed core — see DESIGN.md section 2). Transfers
//! are *flows* over a path of directed [`link::Link`]s; concurrent flows
//! share links by max-min fairness (progressive waterfilling), the standard
//! abstraction for congestion-controlled fabrics at this scale.
//!
//! The fabric is clock-passive: the discrete-event engine in [`crate::sim`]
//! owns time, calls [`Fabric::advance`] to apply progress, and uses
//! [`Fabric::earliest_completion`] to schedule the next network event.

pub mod link;
pub mod qp;

pub use link::{Link, LinkId};

/// Identifier for an active flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

#[derive(Debug, Clone)]
struct Flow {
    path: Vec<LinkId>,
    remaining: f64, // bytes
    rate: f64,      // bytes/s, set by waterfill
    /// Opaque tag the simulation layer uses to route the completion.
    pub tag: u64,
}

/// The fabric: a set of links plus the active flow set.
#[derive(Debug, Default)]
pub struct Fabric {
    links: Vec<Link>,
    flows: Vec<(FlowId, Flow)>,
    next_id: u64,
    rates_dirty: bool,
    /// Total bytes delivered since construction (per link), for utilization
    /// reporting.
    delivered: Vec<f64>,
}

impl Fabric {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a link with capacity in bytes/s; returns its id.
    pub fn add_link(&mut self, name: impl Into<String>, capacity: f64) -> LinkId {
        assert!(capacity > 0.0, "link capacity must be positive");
        let id = LinkId(self.links.len());
        self.links.push(Link::new(name, capacity));
        self.delivered.push(0.0);
        id
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// Start a flow of `bytes` over `path`. An empty path means a
    /// node-local transfer: it completes in zero time (the caller models
    /// any memory-copy cost separately).
    pub fn start_flow(&mut self, path: Vec<LinkId>, bytes: f64, tag: u64) -> FlowId {
        assert!(bytes >= 0.0);
        for l in &path {
            assert!(l.0 < self.links.len(), "bad link id in path");
        }
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.push((
            id,
            Flow {
                path,
                remaining: bytes,
                rate: 0.0,
                tag,
            },
        ));
        self.rates_dirty = true;
        id
    }

    pub fn n_active(&self) -> usize {
        self.flows.len()
    }

    /// Max-min fair rate allocation (progressive waterfilling).
    ///
    /// Repeatedly find the most-contended link (smallest fair share among
    /// its unfrozen flows), freeze those flows at that share, subtract, and
    /// continue. O(L^2 + L*F) worst case; the active flow population is
    /// bounded by queue-pair windows so this stays cheap.
    fn waterfill(&mut self) {
        let nl = self.links.len();
        let mut link_cap: Vec<f64> = self.links.iter().map(|l| l.capacity).collect();
        let mut link_flows: Vec<usize> = vec![0; nl];
        let mut frozen: Vec<bool> = vec![false; self.flows.len()];
        // Empty-path flows are instantaneous; mark them frozen at infinity.
        for (i, (_, f)) in self.flows.iter().enumerate() {
            if f.path.is_empty() {
                frozen[i] = true;
            } else {
                for l in &f.path {
                    link_flows[l.0] += 1;
                }
            }
        }
        let mut rates: Vec<f64> = vec![f64::INFINITY; self.flows.len()];
        loop {
            // Find bottleneck link.
            let mut best: Option<(usize, f64)> = None;
            for l in 0..nl {
                if link_flows[l] == 0 {
                    continue;
                }
                let share = link_cap[l] / link_flows[l] as f64;
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((l, share));
                }
            }
            let Some((bl, share)) = best else { break };
            // Freeze all unfrozen flows through the bottleneck.
            for (i, (_, f)) in self.flows.iter().enumerate() {
                if frozen[i] || !f.path.contains(&LinkId(bl)) {
                    continue;
                }
                frozen[i] = true;
                rates[i] = share;
                for l in &f.path {
                    link_cap[l.0] -= share;
                    link_flows[l.0] -= 1;
                }
            }
            // Numerical guard: capacities should stay ~nonnegative.
            link_cap[bl] = link_cap[bl].max(0.0);
        }
        for (i, (_, f)) in self.flows.iter_mut().enumerate() {
            f.rate = if f.path.is_empty() { f64::INFINITY } else { rates[i] };
        }
        self.rates_dirty = false;
    }

    fn ensure_rates(&mut self) {
        if self.rates_dirty {
            self.waterfill();
        }
    }

    /// Time until the earliest active flow completes, if any.
    pub fn earliest_completion(&mut self) -> Option<f64> {
        self.ensure_rates();
        self.flows
            .iter()
            .map(|(_, f)| {
                if f.remaining <= 0.0 || f.rate.is_infinite() {
                    0.0
                } else {
                    f.remaining / f.rate
                }
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Advance all flows by `dt` seconds; returns tags of completed flows.
    pub fn advance(&mut self, dt: f64) -> Vec<u64> {
        assert!(dt >= 0.0);
        self.ensure_rates();
        let mut done = Vec::new();
        for (_, f) in &mut self.flows {
            let moved = if f.rate.is_infinite() {
                f.remaining
            } else {
                (f.rate * dt).min(f.remaining)
            };
            f.remaining -= moved;
            for l in &f.path {
                self.delivered[l.0] += moved;
            }
            // Tolerate float residue. The threshold is in *bytes*: real
            // transfers are KB+, and sub-millibyte residues otherwise stall
            // the clock (remaining/rate can underflow below one f64 ulp of
            // the current timestamp, so `now + dt == now`).
            if f.remaining <= 1e-3 {
                done.push(f.tag);
            }
        }
        if !done.is_empty() {
            self.flows.retain(|(_, f)| f.remaining > 1e-3);
            self.rates_dirty = true;
        }
        done
    }

    /// Bytes delivered through a link since construction.
    pub fn delivered(&self, id: LinkId) -> f64 {
        self.delivered[id.0]
    }

    /// Current rate of a flow (test/diagnostic hook).
    pub fn flow_rate(&mut self, id: FlowId) -> Option<f64> {
        self.ensure_rates();
        self.flows.iter().find(|(i, _)| *i == id).map(|(_, f)| f.rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6 * b.abs().max(1.0), "{a} != {b}");
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut f = Fabric::new();
        let l = f.add_link("l", 100.0);
        let id = f.start_flow(vec![l], 50.0, 0);
        approx(f.flow_rate(id).unwrap(), 100.0);
        approx(f.earliest_completion().unwrap(), 0.5);
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut f = Fabric::new();
        let l = f.add_link("l", 100.0);
        let a = f.start_flow(vec![l], 100.0, 1);
        let b = f.start_flow(vec![l], 100.0, 2);
        approx(f.flow_rate(a).unwrap(), 50.0);
        approx(f.flow_rate(b).unwrap(), 50.0);
    }

    #[test]
    fn bottleneck_is_min_link_on_path() {
        let mut f = Fabric::new();
        let fast = f.add_link("fast", 100.0);
        let slow = f.add_link("slow", 10.0);
        let id = f.start_flow(vec![fast, slow], 10.0, 0);
        approx(f.flow_rate(id).unwrap(), 10.0);
    }

    #[test]
    fn maxmin_redistributes_leftover() {
        // Flow A crosses both links; flow B only the slow one. B is capped
        // at 5 (share of slow), A then gets the remaining 95 of fast? No:
        // A also crosses slow. slow(10)/2 flows = 5 each; then fast has 95
        // left but A is already frozen at 5.
        let mut f = Fabric::new();
        let fast = f.add_link("fast", 100.0);
        let slow = f.add_link("slow", 10.0);
        let a = f.start_flow(vec![fast, slow], 10.0, 0);
        let b = f.start_flow(vec![slow], 10.0, 1);
        approx(f.flow_rate(a).unwrap(), 5.0);
        approx(f.flow_rate(b).unwrap(), 5.0);
        // And a flow on fast alone now gets the leftover 95.
        let c = f.start_flow(vec![fast], 10.0, 2);
        approx(f.flow_rate(c).unwrap(), 95.0);
    }

    #[test]
    fn advance_completes_in_order() {
        let mut f = Fabric::new();
        let l = f.add_link("l", 10.0);
        f.start_flow(vec![l], 10.0, 7);
        f.start_flow(vec![l], 20.0, 8);
        // Shares: 5 and 5. First completes at t=2.
        let dt = f.earliest_completion().unwrap();
        approx(dt, 2.0);
        let done = f.advance(dt);
        assert_eq!(done, vec![7]);
        // Remaining flow now gets full rate: 10 bytes left / 10 Bps = 1s.
        let dt2 = f.earliest_completion().unwrap();
        approx(dt2, 1.0);
        assert_eq!(f.advance(dt2), vec![8]);
        assert_eq!(f.n_active(), 0);
    }

    #[test]
    fn empty_path_completes_instantly() {
        let mut f = Fabric::new();
        f.start_flow(vec![], 1e9, 3);
        approx(f.earliest_completion().unwrap(), 0.0);
        assert_eq!(f.advance(0.0), vec![3]);
    }

    #[test]
    fn delivered_accounting() {
        let mut f = Fabric::new();
        let l = f.add_link("l", 10.0);
        f.start_flow(vec![l], 10.0, 0);
        f.advance(1.0);
        approx(f.delivered(l), 10.0);
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut f = Fabric::new();
        let l = f.add_link("l", 10.0);
        f.start_flow(vec![l], 0.0, 9);
        assert_eq!(f.advance(0.0), vec![9]);
    }
}
