//! Worker compute model: GPU forward/backward timing.
//!
//! Substitute for the paper's GTX 1080 Ti workers (DESIGN.md section 2):
//! the paper itself abstracts worker compute to a measured
//! time-per-batch (Table 3), so the model is a scaled clock, not FLOPs.
//!
//! Also provides:
//! * GPU *generations* (Figure 1/2: GRID 520 → K80 → M60 → 1080 Ti → V100)
//!   as speed multipliers over the 1080 Ti baseline, used to show the
//!   compute→communication bottleneck shift;
//! * `ZeroCompute` (paper section 4.4 `ZeroComputeEngine`): infinitely fast
//!   forward/backward, isolating the parameter-exchange pipeline.

use crate::dnn::Dnn;

/// Cloud GPU generations from Figure 1, as throughput multipliers relative
/// to the GTX 1080 Ti that Table 3's timings were measured on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Gpu {
    /// EC2 g2 (GRID 520, 2012-era).
    Grid520,
    /// EC2 p2 (K80).
    K80,
    /// EC2 g3 (M60).
    M60,
    /// Local GTX 1080 Ti — the paper's testbed baseline.
    Gtx1080Ti,
    /// EC2 p3 (V100).
    V100,
    /// Infinitely fast compute (ZeroComputeEngine, section 4.4).
    ZeroCompute,
}

impl Gpu {
    /// Approximate ResNet-class throughput relative to a GTX 1080 Ti.
    /// Figure 1 reports a 35x spread between GRID 520 and V100-class parts;
    /// the 1080 Ti sits at roughly 75% of a V100 on these workloads.
    pub fn speedup(self) -> f64 {
        match self {
            Gpu::Grid520 => 0.038, // ~26x slower than 1080 Ti
            Gpu::K80 => 0.17,
            Gpu::M60 => 0.35,
            Gpu::Gtx1080Ti => 1.0,
            Gpu::V100 => 1.33,
            Gpu::ZeroCompute => f64::INFINITY,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Gpu::Grid520 => "GRID 520 (g2)",
            Gpu::K80 => "K80 (p2)",
            Gpu::M60 => "M60 (g3)",
            Gpu::Gtx1080Ti => "GTX 1080 Ti",
            Gpu::V100 => "V100 (p3)",
            Gpu::ZeroCompute => "ZeroCompute",
        }
    }

    pub const GENERATIONS: [Gpu; 5] = [
        Gpu::Grid520,
        Gpu::K80,
        Gpu::M60,
        Gpu::Gtx1080Ti,
        Gpu::V100,
    ];
}

/// Per-worker compute engine: produces fwd/bwd timing for a model.
#[derive(Debug, Clone)]
pub struct ComputeEngine {
    pub gpu: Gpu,
    /// Multiplicative jitter bound for straggler modeling (0.0 = none):
    /// each iteration's compute time is scaled by U(1, 1+jitter).
    pub straggler_jitter: f64,
}

impl ComputeEngine {
    pub fn new(gpu: Gpu) -> Self {
        ComputeEngine {
            gpu,
            straggler_jitter: 0.0,
        }
    }

    pub fn with_jitter(mut self, j: f64) -> Self {
        self.straggler_jitter = j;
        self
    }

    /// Total forward+backward time for one batch of `dnn`.
    pub fn batch_time(&self, dnn: &Dnn) -> f64 {
        if matches!(self.gpu, Gpu::ZeroCompute) {
            return 0.0;
        }
        dnn.time_per_batch / self.gpu.speedup()
    }

    /// Forward-pass share of the batch time. Backward is roughly 2x forward
    /// for these convolutional workloads, so forward ≈ 1/3 of the total.
    pub fn forward_time(&self, dnn: &Dnn) -> f64 {
        self.batch_time(dnn) / 3.0
    }

    /// Backward-pass duration.
    pub fn backward_time(&self, dnn: &Dnn) -> f64 {
        self.batch_time(dnn) - self.forward_time(dnn)
    }

    /// Time (relative to backward-pass start) at which layer `idx`'s
    /// gradient becomes available. Backpropagation visits layers in
    /// *reverse* forward order, so the last layer's gradient is ready
    /// first; layer `idx` is ready once all layers after it have run.
    pub fn grad_ready_offset(&self, dnn: &Dnn, idx: usize) -> f64 {
        assert!(idx < dnn.layers.len());
        let bwd = self.backward_time(dnn);
        let frac_after: f64 = dnn.layers[idx..]
            .iter()
            .map(|l| l.compute_frac)
            .sum();
        bwd * frac_after
    }

    /// Deterministic per-(worker, iteration) straggler factor in
    /// [1, 1+jitter], from a splitmix-style hash so simulations reproduce.
    pub fn straggler_factor(&self, worker: usize, iter: usize) -> f64 {
        if self.straggler_jitter == 0.0 {
            return 1.0;
        }
        let mut z = (worker as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(iter as u64);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        let u = (z >> 11) as f64 / (1u64 << 53) as f64;
        1.0 + self.straggler_jitter * u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::Dnn;

    #[test]
    fn generations_are_monotonic() {
        let mut prev = 0.0;
        for g in Gpu::GENERATIONS {
            assert!(g.speedup() > prev, "{}", g.label());
            prev = g.speedup();
        }
        // Figure 1: ~35x spread between 2012 cloud GPUs and the latest.
        let spread = Gpu::V100.speedup() / Gpu::Grid520.speedup();
        assert!(spread > 30.0 && spread < 40.0, "{spread}");
    }

    #[test]
    fn zero_compute_is_instant() {
        let e = ComputeEngine::new(Gpu::ZeroCompute);
        let d = Dnn::by_abbrev("RN18").unwrap();
        assert_eq!(e.batch_time(&d), 0.0);
        assert_eq!(e.grad_ready_offset(&d, 0), 0.0);
    }

    #[test]
    fn grad_ready_is_reverse_ordered() {
        let e = ComputeEngine::new(Gpu::Gtx1080Ti);
        let d = Dnn::by_abbrev("RN50").unwrap();
        // Last layer's gradient comes out first (smallest offset).
        let first = e.grad_ready_offset(&d, d.layers.len() - 1);
        let last = e.grad_ready_offset(&d, 0);
        assert!(first < last);
        // First layer's gradient only after the whole backward pass.
        assert!((last - e.backward_time(&d)).abs() < 1e-12);
    }

    #[test]
    fn batch_time_scales_with_gpu() {
        let d = Dnn::by_abbrev("RN50").unwrap();
        let slow = ComputeEngine::new(Gpu::K80).batch_time(&d);
        let fast = ComputeEngine::new(Gpu::V100).batch_time(&d);
        assert!(slow > fast);
        assert!((ComputeEngine::new(Gpu::Gtx1080Ti).batch_time(&d) - 0.161).abs() < 1e-9);
    }

    #[test]
    fn straggler_factor_deterministic_and_bounded() {
        let e = ComputeEngine::new(Gpu::Gtx1080Ti).with_jitter(0.1);
        for w in 0..8 {
            for it in 0..10 {
                let f = e.straggler_factor(w, it);
                assert!((1.0..=1.1).contains(&f));
                assert_eq!(f, e.straggler_factor(w, it));
            }
        }
        let none = ComputeEngine::new(Gpu::Gtx1080Ti);
        assert_eq!(none.straggler_factor(3, 5), 1.0);
    }
}
