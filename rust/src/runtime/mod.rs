//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! The build-time Python path (`make artifacts`) lowers the L2 JAX model
//! and L1 Pallas kernels to HLO *text*; this module loads that text,
//! compiles it on the PJRT CPU client, and exposes typed call wrappers.
//! Python never runs at training time — the Rust binary is self-contained
//! once `artifacts/` exists.
//!
//! Interchange is HLO text rather than serialized `HloModuleProto` because
//! jax >= 0.5 emits 64-bit instruction ids that the bundled XLA
//! (xla_extension 0.5.1) rejects; the text parser reassigns ids.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::jsonlite;

/// A PJRT client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

/// A compiled executable with tuple-return convention.
pub struct LoadedFn {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// The AOT manifest (`artifacts/manifest.json`).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub param_count: usize,
    pub padded_size: usize,
    pub chunk_elems: usize,
    pub n_workers: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// (name, offset, len) per key, flat order.
    pub keys: Vec<(String, usize, usize)>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
            dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<name>.hlo.txt` and compile it.
    pub fn load(&self, name: &str) -> Result<LoadedFn> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        Ok(LoadedFn {
            exe,
            name: name.to_string(),
        })
    }

    /// Parse the manifest.
    pub fn manifest(&self) -> Result<Manifest> {
        let path = self.dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let j = jsonlite::parse(&text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("manifest missing {k}"))
        };
        let cfg = j.get("config").context("manifest missing config")?;
        let cfg_get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("config missing {k}"))
        };
        let mut keys = Vec::new();
        for e in j
            .get("keys")
            .and_then(|v| v.as_arr())
            .context("manifest missing keys")?
        {
            keys.push((
                e.get("name")
                    .and_then(|v| v.as_str())
                    .context("key name")?
                    .to_string(),
                e.get("offset").and_then(|v| v.as_usize()).context("key offset")?,
                e.get("len").and_then(|v| v.as_usize()).context("key len")?,
            ));
        }
        Ok(Manifest {
            param_count: get("param_count")?,
            padded_size: get("padded_size")?,
            chunk_elems: get("chunk_elems")?,
            n_workers: get("n_workers")?,
            batch: cfg_get("batch")?,
            seq_len: cfg_get("seq_len")?,
            vocab: cfg_get("vocab")?,
            keys,
        })
    }

    /// Load the initial flat parameters (`params_init.bin`, LE f32).
    pub fn initial_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("params_init.bin");
        let bytes = std::fs::read(&path).with_context(|| format!("read {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("params_init.bin length {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

impl LoadedFn {
    /// Execute with the given inputs; returns the flattened tuple outputs
    /// (AOT lowers with `return_tuple=True`).
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("sync {}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Locate the artifacts directory: `$PHUB_ARTIFACTS`, else `./artifacts`,
/// else walk up from the executable.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PHUB_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    // Fall back to the crate root (benches/examples run from target/).
    let mut p = std::env::current_exe().unwrap_or_default();
    for _ in 0..5 {
        p.pop();
        let cand = p.join("artifacts");
        if cand.exists() {
            return cand;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs (they
    // need the artifacts built); here we test the pure helpers.

    #[test]
    fn literal_roundtrip_f32() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0; 3], &[2, 2]).is_err());
        assert!(literal_i32(&[1; 5], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_literal() {
        let l = literal_scalar(2.5);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 2.5);
    }
}
