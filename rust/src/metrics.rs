//! Lightweight metrics: counters and duration histograms for the live
//! server, examples, and benches. Lock-free counters; fixed log2 buckets.
//!
//! # Observability contract
//!
//! Three layers, each priced for where it sits (the full map, including
//! the flight recorder and the HTTP export plane, is in
//! `coordinator/mod.rs`):
//!
//! * **Global counters** ([`DataPlaneMetrics`]) — one relaxed atomic
//!   increment per event, recorded from core threads and connection
//!   threads. Safe on the exact-zero hot path.
//! * **Per-job attribution** ([`JobMetrics`] via [`JobRegistry`]) — the
//!   same relaxed increments against a job's own metric set. Hot paths
//!   hold a pre-resolved `Arc<JobMetrics>` (cached at admission /
//!   handle creation), so the steady state never takes the registry
//!   lock; the lock is touched only at job init/evict, on error paths
//!   (drops, replays), and by scrapes.
//! * **Snapshots** ([`MetricsSnapshot`], [`HistogramSnapshot`]) — a
//!   point-in-time read of every counter (relaxed loads; each value is
//!   individually atomic, cross-counter skew is bounded by in-flight
//!   increments). This is the only read path the HTTP status endpoint
//!   uses, so scraping can never perturb a round beyond cache traffic.
//!
//! This module stays dependency-free (no metrics→coordinator edge): the
//! mapping from engine errors to the per-reason drop counters lives at
//! the recording site in `coordinator/server.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins numeric gauge (current usage / configured quota
/// cells on a job's metric set). Relaxed stores and loads, same pricing
/// as [`Counter`].
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Saturating decrement (a racing double-release must never wrap).
    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A set-once configuration gauge: a small u8-encoded enum recorded at
/// startup (e.g. which SIMD tier or placement mode a server selected) so
/// operators and tests can assert which path actually ran. The encoding
/// is defined by the writer — see
/// `coordinator::kernels::KernelTier::from_u8` and
/// `coordinator::mapping::PlacementMode::from_u8`; this module stays a
/// plain u8 cell to avoid a metrics→coordinator dependency.
#[derive(Debug, Default)]
pub struct Setting(AtomicU8);

impl Setting {
    pub const fn new() -> Self {
        Setting(AtomicU8::new(0))
    }

    pub fn set(&self, v: u8) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u8 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Data-plane counters for the coordinator's core fabric — one instance
/// per [`crate::coordinator::PHubServer`], shared by every core thread
/// and read through `PHubServer::metrics()`.
///
/// These replace the old stderr reporting in the core loop: a dropped or
/// invalid message is an operational signal (a buggy client, a torn
/// frame, a replay race), and counters make it observable without
/// scraping logs.
#[derive(Debug, Default)]
pub struct DataPlaneMetrics {
    /// Messages a core dropped because the engine rejected them (unknown
    /// job/chunk, duplicate push, future round, aggregation error). The
    /// violator's round simply never completes; shared cores are never
    /// harmed. This is the aggregate; the `drop_*` counters below split
    /// it by reject reason so a soak can assert *which* drops happened.
    pub dropped_messages: Counter,
    /// Engine rejects split by reason (each increments alongside
    /// `dropped_messages`): the push named a job this core has no shard
    /// for.
    pub drop_unknown_job: Counter,
    /// The push named a chunk the job does not place on this core.
    pub drop_unknown_chunk: Counter,
    /// The worker double-pushed a chunk within one round.
    pub drop_duplicate: Counter,
    /// The push was tagged for a round its chunk has not opened yet.
    pub drop_future_round: Counter,
    /// An aggregation-level violation (worker out of range, bad payload
    /// length, malformed quantized bytes, ...).
    pub drop_agg: Counter,
    /// Quantized pushes dropped at the core for malformed `QuantGrad`
    /// payloads before reaching the engine (the transport validates at
    /// the edge, so a non-zero count means a bug or a torn message).
    pub dropped_quant_payloads: Counter,
    /// `RollbackRound` control messages processed by cores (mid-round
    /// recovery events × cores).
    pub rollbacks: Counter,
    /// Read/write deadlines that fired on a connection (leader round
    /// deadline or a peer's socket timeout surfaced to us).
    pub timeouts: Counter,
    /// Relay uplink reconnection attempts after a failed rendezvous
    /// with the parent (each backoff-then-retry counts once).
    pub redials: Counter,
    /// Relay uplinks that exhausted their redial budget and failed the
    /// job with a typed error instead of spinning forever.
    pub uplink_giveups: Counter,
    /// Stalled-worker round deadlines that converted a silent mid-round
    /// stall into the epoch-bump/rollback/replay recovery path.
    pub deadline_trips: Counter,
    /// Frames recognized as replays/duplicates of already-absorbed
    /// pushes (stale-epoch drops at the connection, replayed or
    /// stale-tagged pushes at the engine) and discarded idempotently.
    pub replayed_frames: Counter,
    /// Quantizer error-feedback residual checkpoint chunks *committed*
    /// at round completion (`ResidualSave` frames staged during the
    /// round and published at its boundary), one count per chunk.
    pub residual_saves: Counter,
    /// Successor connections that were handed a stored residual
    /// checkpoint at admission (`ResidualChunk` restore, one per
    /// restored connection).
    pub residual_restores: Counter,
    /// Admissions refused because the leader was shedding load (the
    /// overload watermark tripped, or an operator forced shedding).
    /// Every refusal is typed and retriable on the wire (`Op::Refused`).
    pub refused_overload: Counter,
    /// Admissions refused because the new job would exceed a per-tenant
    /// or leader-wide capacity quota (worker slots, model elements,
    /// aggregate totals).
    pub refused_quota: Counter,
    /// Admissions refused because the leader already hosts its maximum
    /// number of concurrent jobs. Never counted for a re-`Hello` of a
    /// job that is already resident.
    pub refused_job_cap: Counter,
    /// Jobs evicted for idling past the configured horizon, with their
    /// parameter state staged for handoff (see `coordinator::transport`).
    pub idle_evictions: Counter,
    /// Evicted jobs readmitted from staged handoff state (the tenant
    /// came back and resumed bit-exactly).
    pub readmissions: Counter,
    /// Fair-scheduler deferrals: sweeps in which a job's ports still had
    /// traffic queued after its deficit budget was spent, so the
    /// backlog waited for the next refill while neighbours ran.
    pub sched_deferrals: Counter,
    /// The SIMD kernel tier this server's cores dispatch to —
    /// `coordinator::kernels::KernelTier as u8`
    /// (0 scalar, 1 SSE2, 2 AVX2). Set once by `PHubServer::start`.
    pub kernel_tier: Setting,
    /// The chunk→core placement mode —
    /// `coordinator::mapping::PlacementMode as u8`
    /// (0 interleave, 1 affine). Set once by `PHubServer::start`.
    pub placement_mode: Setting,
    /// Per-job (per-tenant) metric sets, registered at job init and
    /// dropped at eviction. See the lock discipline on [`JobRegistry`].
    pub per_job: JobRegistry,
}

impl DataPlaneMetrics {
    /// Point-in-time snapshot of every counter, including the per-job
    /// sets. Relaxed loads: each value is individually exact,
    /// cross-counter skew is bounded by increments in flight during the
    /// read. This is the status endpoint's only read path.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            dropped_messages: self.dropped_messages.get(),
            drop_unknown_job: self.drop_unknown_job.get(),
            drop_unknown_chunk: self.drop_unknown_chunk.get(),
            drop_duplicate: self.drop_duplicate.get(),
            drop_future_round: self.drop_future_round.get(),
            drop_agg: self.drop_agg.get(),
            dropped_quant_payloads: self.dropped_quant_payloads.get(),
            rollbacks: self.rollbacks.get(),
            timeouts: self.timeouts.get(),
            redials: self.redials.get(),
            uplink_giveups: self.uplink_giveups.get(),
            deadline_trips: self.deadline_trips.get(),
            replayed_frames: self.replayed_frames.get(),
            residual_saves: self.residual_saves.get(),
            residual_restores: self.residual_restores.get(),
            refused_overload: self.refused_overload.get(),
            refused_quota: self.refused_quota.get(),
            refused_job_cap: self.refused_job_cap.get(),
            idle_evictions: self.idle_evictions.get(),
            readmissions: self.readmissions.get(),
            sched_deferrals: self.sched_deferrals.get(),
            kernel_tier: self.kernel_tier.get(),
            placement_mode: self.placement_mode.get(),
            jobs: self.per_job.snapshot(),
        }
    }
}

/// One job's (tenant's) metric set. Hot-path increments are the same
/// relaxed atomics as the global counters; holders cache the
/// `Arc<JobMetrics>` at admission so no lookup happens per round.
#[derive(Debug, Default)]
pub struct JobMetrics {
    /// Worker-rounds completed: one count per (worker, round) pair that
    /// ran to completion (a job with `w` workers advances this by `w`
    /// per global round).
    pub rounds_completed: Counter,
    /// Gradient payload bytes received from this job's workers.
    pub push_bytes: Counter,
    /// Parameter reply bytes written back to this job's workers.
    pub pull_bytes: Counter,
    /// Wall time from a worker's first push of a round to that round's
    /// completion (includes replay time after a mid-round rollback).
    pub round_latency: Histogram,
    /// Engine rejects attributed to this job (see the global `drop_*`
    /// split for reasons).
    pub drops: Counter,
    /// Replayed/stale frames attributed to this job.
    pub replays: Counter,
    /// Rollback events attributed to this job (per core that applied
    /// one).
    pub rollbacks: Counter,
    /// Fair-scheduler deferrals charged to this job (its own backlog
    /// waiting on its own budget — the guardrail working as intended).
    pub deferrals: Counter,
    /// Typed admission refusals issued against this tenant's namespace
    /// (over-quota worker slots on a live job, and — when the tenant's
    /// metric set survives — repeated refused `Hello`s).
    pub refusals: Counter,
    /// Configured fair-schedule weight (set at admission; quota view).
    pub sched_weight: Gauge,
    /// Model elements this job occupies (set at admission; quota view).
    pub model_elems: Gauge,
    /// Worker slots the job's spec declares (set at admission).
    pub n_workers: Gauge,
    /// Currently connected workers (admission increments, disconnect
    /// decrements; an idle job shows 0 and is eligible for eviction).
    pub live_workers: Gauge,
}

impl JobMetrics {
    fn snapshot(&self, job: u32) -> JobMetricsSnapshot {
        JobMetricsSnapshot {
            job,
            rounds_completed: self.rounds_completed.get(),
            push_bytes: self.push_bytes.get(),
            pull_bytes: self.pull_bytes.get(),
            drops: self.drops.get(),
            replays: self.replays.get(),
            rollbacks: self.rollbacks.get(),
            deferrals: self.deferrals.get(),
            refusals: self.refusals.get(),
            sched_weight: self.sched_weight.get(),
            model_elems: self.model_elems.get(),
            n_workers: self.n_workers.get(),
            live_workers: self.live_workers.get(),
            round_latency: self.round_latency.snapshot(),
        }
    }
}

/// Point-in-time copy of one job's [`JobMetrics`].
#[derive(Debug, Clone)]
pub struct JobMetricsSnapshot {
    pub job: u32,
    pub rounds_completed: u64,
    pub push_bytes: u64,
    pub pull_bytes: u64,
    pub drops: u64,
    pub replays: u64,
    pub rollbacks: u64,
    pub deferrals: u64,
    pub refusals: u64,
    pub sched_weight: u64,
    pub model_elems: u64,
    pub n_workers: u64,
    pub live_workers: u64,
    pub round_latency: HistogramSnapshot,
}

/// Registry of per-job metric sets.
///
/// Lock discipline: the interior mutex is a control-plane lock — taken
/// at job registration/eviction, by snapshots/scrapes, and on error
/// paths that need a job lookup (drops, replays — both off the
/// steady-state round). The exact-zero hot path never calls into this
/// type; it increments through an `Arc<JobMetrics>` resolved once at
/// admission.
#[derive(Debug, Default)]
pub struct JobRegistry {
    jobs: Mutex<HashMap<u32, Arc<JobMetrics>>>,
}

impl JobRegistry {
    /// Get-or-create the metric set for `job`.
    pub fn register(&self, job: u32) -> Arc<JobMetrics> {
        let mut map = self.jobs.lock().expect("job metrics lock");
        map.entry(job).or_default().clone()
    }

    /// The metric set for `job`, if registered.
    pub fn get(&self, job: u32) -> Option<Arc<JobMetrics>> {
        self.jobs.lock().expect("job metrics lock").get(&job).cloned()
    }

    /// Drop `job`'s metric set (eviction; scrape history goes with it).
    pub fn remove(&self, job: u32) {
        self.jobs.lock().expect("job metrics lock").remove(&job);
    }

    /// Snapshot every registered job, ordered by job id.
    pub fn snapshot(&self) -> Vec<JobMetricsSnapshot> {
        let map = self.jobs.lock().expect("job metrics lock");
        let mut out: Vec<JobMetricsSnapshot> =
            map.iter().map(|(job, m)| m.snapshot(*job)).collect();
        drop(map);
        out.sort_by_key(|s| s.job);
        out
    }
}

/// Point-in-time copy of a [`DataPlaneMetrics`] (global counters +
/// per-job sets). Built by [`DataPlaneMetrics::snapshot`].
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub dropped_messages: u64,
    pub drop_unknown_job: u64,
    pub drop_unknown_chunk: u64,
    pub drop_duplicate: u64,
    pub drop_future_round: u64,
    pub drop_agg: u64,
    pub dropped_quant_payloads: u64,
    pub rollbacks: u64,
    pub timeouts: u64,
    pub redials: u64,
    pub uplink_giveups: u64,
    pub deadline_trips: u64,
    pub replayed_frames: u64,
    pub residual_saves: u64,
    pub residual_restores: u64,
    pub refused_overload: u64,
    pub refused_quota: u64,
    pub refused_job_cap: u64,
    pub idle_evictions: u64,
    pub readmissions: u64,
    pub sched_deferrals: u64,
    pub kernel_tier: u8,
    pub placement_mode: u8,
    pub jobs: Vec<JobMetricsSnapshot>,
}

impl MetricsSnapshot {
    /// The global counters as (name, value) pairs — the iteration order
    /// the Prometheus exposition uses.
    pub fn counters(&self) -> [(&'static str, u64); 21] {
        [
            ("dropped_messages", self.dropped_messages),
            ("drop_unknown_job", self.drop_unknown_job),
            ("drop_unknown_chunk", self.drop_unknown_chunk),
            ("drop_duplicate", self.drop_duplicate),
            ("drop_future_round", self.drop_future_round),
            ("drop_agg", self.drop_agg),
            ("dropped_quant_payloads", self.dropped_quant_payloads),
            ("rollbacks", self.rollbacks),
            ("timeouts", self.timeouts),
            ("redials", self.redials),
            ("uplink_giveups", self.uplink_giveups),
            ("deadline_trips", self.deadline_trips),
            ("replayed_frames", self.replayed_frames),
            ("residual_saves", self.residual_saves),
            ("residual_restores", self.residual_restores),
            ("refused_overload", self.refused_overload),
            ("refused_quota", self.refused_quota),
            ("refused_job_cap", self.refused_job_cap),
            ("idle_evictions", self.idle_evictions),
            ("readmissions", self.readmissions),
            ("sched_deferrals", self.sched_deferrals),
        ]
    }
}

/// Power-of-two bucketed latency histogram (nanoseconds, 48 buckets:
/// 1 ns .. ~78 h).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 48],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn record_ns(&self, ns: u64) {
        let b = (64 - ns.max(1).leading_zeros() as usize - 1).min(47);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        self.snapshot().mean_ns()
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        self.snapshot().quantile_ns(q)
    }

    /// Lock-free point-in-time copy: relaxed loads of every bucket.
    /// Records racing the snapshot land wholly in this copy or the
    /// next; a bucket is never torn (each cell is an atomic), though a
    /// racing record may momentarily show in `buckets` before `count`
    /// or vice versa — merge math stays exact either way.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`], mergeable across instances
/// (e.g. per-core histograms folded into one job view).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; 48],
    pub sum_ns: u64,
    pub count: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot {
            buckets: [0; 48],
            sum_ns: 0,
            count: 0,
        }
    }
}

impl HistogramSnapshot {
    /// Fold `other` into `self` (bucket-wise addition; exact).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.sum_ns += other.sum_ns;
        self.count += other.count;
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.count as f64
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (b + 1);
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn setting_basics() {
        let s = Setting::new();
        assert_eq!(s.get(), 0);
        s.set(2);
        assert_eq!(s.get(), 2);
        s.set(1);
        assert_eq!(s.get(), 1);
        // Default matches new (DataPlaneMetrics derives Default).
        assert_eq!(Setting::default().get(), 0);
    }

    #[test]
    fn gauge_set_add_dec_saturates() {
        let g = Gauge::new();
        g.set(2);
        g.add(3);
        assert_eq!(g.get(), 5);
        for _ in 0..7 {
            g.dec();
        }
        assert_eq!(g.get(), 0, "dec saturates at zero");
    }

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::new();
        for ns in [100u64, 200, 300] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ns() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000);
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99);
        // p50 of 1..=1000 us is ~500 us; bucket upper bound within 2x.
        assert!(p50 >= 500_000 && p50 <= 2 * 1_048_576, "{p50}");
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.9), 0);
        assert_eq!(h.snapshot(), HistogramSnapshot::default());
    }

    /// Exact bucket-edge placement: bucket `b` holds `[2^b, 2^(b+1))`,
    /// so `2^k` lands in bucket `k` and `2^k - 1` in bucket `k - 1`;
    /// 0/1 clamp into bucket 0 and everything at or above `2^47`
    /// (`u64::MAX` included) collapses into bucket 47.
    #[test]
    fn histogram_bucket_edges_exact() {
        let h = Histogram::new();
        h.record_ns(0); // clamped to 1
        h.record_ns(1);
        assert_eq!(h.snapshot().buckets[0], 2);
        for k in 1..48usize {
            let h = Histogram::new();
            h.record_ns(1u64 << k);
            h.record_ns((1u64 << k) - 1);
            let s = h.snapshot();
            assert_eq!(s.buckets[k], 1, "2^{k} must land in bucket {k}");
            assert_eq!(s.buckets[k - 1], 1, "2^{k}-1 must land in bucket {}", k - 1);
            assert_eq!(s.count, 2);
        }
        let h = Histogram::new();
        h.record_ns(1u64 << 47);
        h.record_ns(1u64 << 63);
        h.record_ns(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.buckets[47], 3);
        assert_eq!(s.quantile_ns(1.0), 1u64 << 48);
    }

    #[test]
    fn histogram_snapshot_merge_is_exact() {
        let a = Histogram::new();
        let b = Histogram::new();
        for ns in [1u64, 100, 10_000] {
            a.record_ns(ns);
        }
        for ns in [1_000_000u64, 50_000_000] {
            b.record_ns(ns);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.count, 5);
        assert_eq!(merged.sum_ns, 1 + 100 + 10_000 + 1_000_000 + 50_000_000);
        assert_eq!(merged.buckets.iter().sum::<u64>(), 5);
        // Merging an empty snapshot is the identity.
        let before = merged.clone();
        merged.merge(&HistogramSnapshot::default());
        assert_eq!(merged, before);
        assert!(merged.quantile_ns(0.2) <= merged.quantile_ns(0.99));
    }

    #[test]
    fn histogram_concurrent_records_all_land() {
        let h = Arc::new(Histogram::new());
        let workers: Vec<_> = (0..4u64)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record_ns(t * 10_000 + i + 1);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 40_000);
        assert_eq!(s.buckets.iter().sum::<u64>(), 40_000);
        assert!(s.mean_ns() > 0.0);
    }

    #[test]
    fn job_registry_register_get_remove() {
        let reg = JobRegistry::default();
        let a = reg.register(7);
        let again = reg.register(7);
        assert!(Arc::ptr_eq(&a, &again), "register is get-or-create");
        a.rounds_completed.add(3);
        a.push_bytes.add(1024);
        a.round_latency.record_ns(500);
        reg.register(3).drops.inc();
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].job, 3, "snapshot ordered by job id");
        assert_eq!(snap[0].drops, 1);
        assert_eq!(snap[1].job, 7);
        assert_eq!(snap[1].rounds_completed, 3);
        assert_eq!(snap[1].push_bytes, 1024);
        assert_eq!(snap[1].round_latency.count, 1);
        reg.remove(7);
        assert!(reg.get(7).is_none());
        assert_eq!(reg.snapshot().len(), 1);
    }

    #[test]
    fn data_plane_snapshot_carries_reason_split_and_jobs() {
        let m = DataPlaneMetrics::default();
        m.dropped_messages.inc();
        m.drop_future_round.inc();
        m.per_job.register(1).replays.add(2);
        let s = m.snapshot();
        assert_eq!(s.dropped_messages, 1);
        assert_eq!(s.drop_future_round, 1);
        assert_eq!(s.drop_unknown_job, 0);
        assert_eq!(s.jobs.len(), 1);
        assert_eq!(s.jobs[0].replays, 2);
        let names: Vec<&str> = s.counters().iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"drop_duplicate"));
        assert_eq!(
            s.counters().iter().map(|(_, v)| v).sum::<u64>(),
            2,
            "dropped_messages + drop_future_round"
        );
    }
}
