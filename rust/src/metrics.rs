//! Lightweight metrics: counters and duration histograms for the live
//! server, examples, and benches. Lock-free counters; fixed log2 buckets.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A set-once configuration gauge: a small u8-encoded enum recorded at
/// startup (e.g. which SIMD tier or placement mode a server selected) so
/// operators and tests can assert which path actually ran. The encoding
/// is defined by the writer — see
/// `coordinator::kernels::KernelTier::from_u8` and
/// `coordinator::mapping::PlacementMode::from_u8`; this module stays a
/// plain u8 cell to avoid a metrics→coordinator dependency.
#[derive(Debug, Default)]
pub struct Setting(AtomicU8);

impl Setting {
    pub const fn new() -> Self {
        Setting(AtomicU8::new(0))
    }

    pub fn set(&self, v: u8) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u8 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Data-plane counters for the coordinator's core fabric — one instance
/// per [`crate::coordinator::PHubServer`], shared by every core thread
/// and read through `PHubServer::metrics()`.
///
/// These replace the old stderr reporting in the core loop: a dropped or
/// invalid message is an operational signal (a buggy client, a torn
/// frame, a replay race), and counters make it observable without
/// scraping logs.
#[derive(Debug, Default)]
pub struct DataPlaneMetrics {
    /// Messages a core dropped because the engine rejected them (unknown
    /// job/chunk, duplicate push, future round, aggregation error). The
    /// violator's round simply never completes; shared cores are never
    /// harmed.
    pub dropped_messages: Counter,
    /// Quantized pushes dropped at the core for malformed `QuantGrad`
    /// payloads before reaching the engine (the transport validates at
    /// the edge, so a non-zero count means a bug or a torn message).
    pub dropped_quant_payloads: Counter,
    /// `RollbackRound` control messages processed by cores (mid-round
    /// recovery events × cores).
    pub rollbacks: Counter,
    /// Read/write deadlines that fired on a connection (leader round
    /// deadline or a peer's socket timeout surfaced to us).
    pub timeouts: Counter,
    /// Relay uplink reconnection attempts after a failed rendezvous
    /// with the parent (each backoff-then-retry counts once).
    pub redials: Counter,
    /// Relay uplinks that exhausted their redial budget and failed the
    /// job with a typed error instead of spinning forever.
    pub uplink_giveups: Counter,
    /// Stalled-worker round deadlines that converted a silent mid-round
    /// stall into the epoch-bump/rollback/replay recovery path.
    pub deadline_trips: Counter,
    /// Frames recognized as replays/duplicates of already-absorbed
    /// pushes (stale-epoch drops at the connection, replayed or
    /// stale-tagged pushes at the engine) and discarded idempotently.
    pub replayed_frames: Counter,
    /// Quantizer error-feedback residual checkpoint chunks *committed*
    /// at round completion (`ResidualSave` frames staged during the
    /// round and published at its boundary), one count per chunk.
    pub residual_saves: Counter,
    /// Successor connections that were handed a stored residual
    /// checkpoint at admission (`ResidualChunk` restore, one per
    /// restored connection).
    pub residual_restores: Counter,
    /// The SIMD kernel tier this server's cores dispatch to —
    /// `coordinator::kernels::KernelTier as u8`
    /// (0 scalar, 1 SSE2, 2 AVX2). Set once by `PHubServer::start`.
    pub kernel_tier: Setting,
    /// The chunk→core placement mode —
    /// `coordinator::mapping::PlacementMode as u8`
    /// (0 interleave, 1 affine). Set once by `PHubServer::start`.
    pub placement_mode: Setting,
}

/// Power-of-two bucketed latency histogram (nanoseconds, 48 buckets:
/// 1 ns .. ~78 h).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 48],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn record_ns(&self, ns: u64) {
        let b = (64 - ns.max(1).leading_zeros() as usize - 1).min(47);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (b + 1);
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn setting_basics() {
        let s = Setting::new();
        assert_eq!(s.get(), 0);
        s.set(2);
        assert_eq!(s.get(), 2);
        s.set(1);
        assert_eq!(s.get(), 1);
        // Default matches new (DataPlaneMetrics derives Default).
        assert_eq!(Setting::default().get(), 0);
    }

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::new();
        for ns in [100u64, 200, 300] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ns() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000);
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99);
        // p50 of 1..=1000 us is ~500 us; bucket upper bound within 2x.
        assert!(p50 >= 500_000 && p50 <= 2 * 1_048_576, "{p50}");
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.9), 0);
    }
}
