//! In-crate property-based testing support.
//!
//! The offline environment lacks `proptest`, so this module provides the
//! minimal machinery the test suite needs: a deterministic splitmix64 RNG,
//! generator helpers, and a case-runner that reports the failing seed so
//! counterexamples reproduce exactly.

/// Deterministic splitmix64 RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi)` (hi > lo).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// f32 in [-scale, scale).
    pub fn f32_sym(&mut self, scale: f32) -> f32 {
        (self.f64() as f32 * 2.0 - 1.0) * scale
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Vec of f32 in [-scale, scale).
    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_sym(scale)).collect()
    }

    /// Random weights for partition tests (1..=max each).
    pub fn weights(&mut self, len: usize, max: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(1, max + 1)).collect()
    }
}

/// Run `cases` property cases; on failure, panic with the seed that
/// reproduces it. The property returns `Err(msg)` to fail.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.usize_in(3, 10);
            assert!((3..10).contains(&x));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
            let s = r.f32_sym(2.0);
            assert!((-2.0..2.0).contains(&s));
        }
    }

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counts", 17, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |rng| {
            if rng.f64() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }
}
