//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim provides
//! exactly the surface the `phub` crate uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Like the real crate, [`Error`] deliberately does **not**
//! implement `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion to exist.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root-cause chain, outermost first (diagnostic helper).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> + '_ {
        let mut next = self
            .source
            .as_deref()
            .map(|e| e as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for (i, cause) in self.chain().enumerate() {
            if i == 0 {
                write!(f, "\n\nCaused by:")?;
            }
            write!(f, "\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (on `Result`) or turn `None` into an error.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built as in [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::other("disk on fire"))?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_context() {
        let e = io_fail().context("while saving").unwrap_err();
        assert_eq!(e.to_string(), "while saving: disk on fire");
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
