//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real `xla_extension` shared library is not bundled in this
//! environment, so this crate provides the exact API surface
//! `phub::runtime` compiles against. [`Literal`] is a real in-memory
//! implementation (the pure helpers and their unit tests work); the PJRT
//! client/compile/execute entry points return a clear "PJRT unavailable"
//! error at run time, which makes the artifact-dependent integration tests
//! skip rather than fail.

use std::fmt;

/// Stub error type (the real crate's `Error` is richer).
#[derive(Debug, Clone, PartialEq)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable (stub xla crate; xla_extension is not \
         bundled in this offline environment — run `make artifacts` on a \
         machine with the real toolchain)"
    ))
}

/// Element storage for [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// An in-memory literal: typed flat data plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    pub data: Data,
    pub dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
        }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            data: Data::F32(vec![v]),
            dims: vec![],
        }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Flat copy of the elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// First element (scalar extraction).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    /// Explode a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the real bindings).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parse HLO text {path:?}")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("create PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_extract() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn pjrt_entry_points_report_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
