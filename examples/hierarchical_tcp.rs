//! Hierarchical (leader-of-leaders) PHub over TCP: rack relays feeding
//! one root, next to the flat deployment they replace.
//!
//! Spawns one root leader plus `--racks` RackRelay leaders (paper
//! section 3.4, Figure 19), each serving `--workers` leaf workers over
//! localhost TCP. Every relay tall-aggregates its rack and streams raw
//! per-chunk sums upstream over the same v2 chunk frames its own workers
//! use; the root runs the optimizer exactly once per round and fans
//! parameters back down. The same leaves then run against a single flat
//! leader, and because the example uses dyadic gradients with
//! power-of-two hyperparameters, the two deployments' final models are
//! asserted **bit-identical** — association of the sum provably does not
//! matter here.
//!
//! The speedup printout is deliberately honest: on localhost every hop
//! shares one memory bus, so the "cross-rack core" is as fat as links
//! get and the paper's benefit condition
//! (`hierarchy::hierarchical_beneficial`) predicts the extra level only
//! costs. The model's thin-core regime — where hierarchy wins — is
//! printed alongside for contrast.
//!
//! Run: `cargo run --release --example hierarchical_tcp -- [--racks 2]
//! [--workers 2]`

use phub::cli::Args;
use phub::coordinator::hierarchy::{b_bn, hierarchical_beneficial, ring_step_cost, HierBandwidths};
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, RelayConfig, TcpLeader, TcpWorker};

/// Model-time per unit of model exchanged, flat vs two-level (the two
/// sides of the paper's benefit inequality); ratio = predicted speedup.
fn predicted_speedup(bw: HierBandwidths, n: usize, racks: usize) -> f64 {
    let nf = n as f64;
    let flat = ((nf - 1.0) / b_bn(bw, racks)).max(1.0 / bw.b_wkr);
    let hier = (nf / bw.b_pbox).max(1.0 / bw.b_wkr) + ring_step_cost(bw, racks);
    flat / hier
}

fn run_leaves(
    addrs: &[std::net::SocketAddr],
    job: u32,
    spec: JobSpec,
    workers: u32,
    model: usize,
    rounds: usize,
) -> anyhow::Result<Vec<f32>> {
    let joins: Vec<_> = addrs
        .iter()
        .enumerate()
        .flat_map(|(ri, &addr)| {
            (0..workers).map(move |w| {
                let seat = ri * workers as usize + w as usize;
                std::thread::spawn(move || -> anyhow::Result<Vec<f32>> {
                    let mut worker = TcpWorker::connect(addr, job, spec)?;
                    // Dyadic gradients (multiples of 1/8, bounded) keep
                    // f32 sums exact under any association, so flat and
                    // two-level runs agree bitwise.
                    let grad: Vec<f32> = (0..model)
                        .map(|i| ((i + seat) % 16) as f32 * 0.125)
                        .collect();
                    let mut m = Vec::new();
                    for _ in 0..rounds {
                        m = worker.push_pull(&grad)?;
                    }
                    worker.bye();
                    Ok(m)
                })
            })
        })
        .collect();
    let mut models = Vec::new();
    for j in joins {
        models.push(j.join().unwrap()?);
    }
    assert!(
        models.windows(2).all(|w| w[0] == w[1]),
        "synchronous leaves must agree"
    );
    Ok(models.pop().unwrap())
}

fn main() -> anyhow::Result<()> {
    let a = Args::from_env();
    let racks = a.get_usize("racks", 2) as u32;
    let workers = a.get_usize("workers", 2) as u32;
    let model = a.get_usize("model-kb", 256) * 1024 / 4;
    let rounds = a.get_usize("rounds", 10);
    let spec = JobSpec {
        model_elems: model as u64,
        chunk_elems: 8192,
        n_workers: workers,
        lr: 0.25,
        momentum: 0.5,
    };

    // Two-level: one root, `racks` relays, `workers` leaves per relay.
    let root = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2))?;
    let relays: Vec<_> = (0..racks)
        .map(|_| {
            TcpLeader::serve_relay(
                "127.0.0.1:0",
                ServerConfig::cores(2),
                RelayConfig {
                    parent: root.local_addr().to_string(),
                    racks,
                },
            )
        })
        .collect::<Result<_, _>>()?;
    let relay_addrs: Vec<_> = relays.iter().map(|r| r.local_addr()).collect();
    println!(
        "root on {}, {racks} rack relays x {workers} workers, {} KB model",
        root.local_addr(),
        model * 4 / 1024
    );
    let t0 = std::time::Instant::now();
    let hier_model = run_leaves(&relay_addrs, 1, spec, workers, model, rounds)?;
    let dt_hier = t0.elapsed().as_secs_f64();

    // Flat: same leaves, one leader, one level.
    let flat = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2))?;
    let flat_spec = JobSpec {
        n_workers: racks * workers,
        ..spec
    };
    let t0 = std::time::Instant::now();
    let flat_addr = [flat.local_addr()];
    let flat_model = run_leaves(&flat_addr, 1, flat_spec, racks * workers, model, rounds)?;
    let dt_flat = t0.elapsed().as_secs_f64();

    assert_eq!(
        hier_model, flat_model,
        "two-level must be bit-identical to flat"
    );
    println!(
        "  two-level model == flat model (bitwise), model[0..2]={:?}",
        &hier_model[..2]
    );

    // Predicted vs observed. Localhost's "cross-rack core" is a shared
    // memory bus — effectively infinite next to any NIC — so the model
    // predicts hierarchy can only add overhead here; its thin-core
    // regime (the paper's oversubscribed datacenter core) is where the
    // extra level pays.
    let localhost = HierBandwidths {
        b_pbox: 10e9,
        b_core: 1e12,
        b_wkr: 10e9,
    };
    let thin = HierBandwidths {
        b_pbox: 12.5e9,
        b_core: 2.5e9,
        b_wkr: 1.25e9,
    };
    let (n, r) = (workers as usize, racks as usize);
    println!(
        "  flat {:.1} rounds/s, two-level {:.1} rounds/s: observed speedup {:.2}x, \
         predicted on localhost-like fat core {:.2}x (beneficial: {})",
        rounds as f64 / dt_flat,
        rounds as f64 / dt_hier,
        dt_flat / dt_hier,
        predicted_speedup(localhost, n, r),
        hierarchical_beneficial(localhost, n, r),
    );
    println!(
        "  for contrast, paper-regime thin core (16 workers/rack, 4 racks): \
         predicted speedup {:.2}x (beneficial: {})",
        predicted_speedup(thin, 16, 4),
        hierarchical_beneficial(thin, 16, 4),
    );
    println!("hierarchical_tcp OK");
    Ok(())
}
