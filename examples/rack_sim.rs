//! Rack-scale what-if explorer: sweep PS configurations, stacks, networks,
//! worker counts, and GPU generations over the simulated testbed — the
//! tool a cluster operator would use to size a PHub deployment.
//!
//! Run: `cargo run --release --example rack_sim -- [--dnn RN50] [--racks 4]`

use phub::cli::Args;
use phub::compute::Gpu;
use phub::config::{ClusterConfig, ExchangeConfig, NetConfig, PsConfig, Stack};
use phub::coordinator::hierarchy;
use phub::dnn::Dnn;
use phub::sim;

fn main() {
    let a = Args::from_env();
    let dnn = Dnn::by_abbrev(a.get_or("dnn", "RN50")).expect("unknown dnn");
    let racks = a.get_usize("racks", 4);

    println!("=== {} on the simulated rack (8 workers) ===\n", dnn.name);
    println!(
        "{:<26} {:>9} {:>12} {:>10}",
        "configuration", "iter ms", "samples/s", "overhead%"
    );
    let configs: Vec<(&str, ClusterConfig)> = vec![
        (
            "MXNet TCP / CS / 10G",
            ClusterConfig::paper_testbed()
                .with_ps(PsConfig::ColocatedSharded)
                .with_stack(Stack::MxnetTcp)
                .with_net(NetConfig::cloud_10g())
                .with_exchange(ExchangeConfig::mxnet()),
        ),
        (
            "MXNet IB / CS / 10G",
            ClusterConfig::paper_testbed()
                .with_ps(PsConfig::ColocatedSharded)
                .with_stack(Stack::MxnetIb)
                .with_net(NetConfig::cloud_10g())
                .with_exchange(ExchangeConfig::mxnet()),
        ),
        (
            "PHub PShard (CS) / 10G",
            ClusterConfig::paper_testbed()
                .with_ps(PsConfig::ColocatedSharded)
                .with_net(NetConfig::cloud_10g()),
        ),
        (
            "PHub PBox / 10G",
            ClusterConfig::paper_testbed().with_net(NetConfig::cloud_10g()),
        ),
        (
            "MXNet IB / CS / 56G",
            ClusterConfig::paper_testbed()
                .with_ps(PsConfig::ColocatedSharded)
                .with_stack(Stack::MxnetIb)
                .with_exchange(ExchangeConfig::mxnet()),
        ),
        ("PHub PBox / 56G", ClusterConfig::paper_testbed()),
    ];
    for (name, c) in &configs {
        let r = sim::simulate(c, &dnn, Gpu::Gtx1080Ti);
        println!(
            "{:<26} {:>9.2} {:>12.1} {:>9.0}%",
            name,
            r.iter_time * 1e3,
            r.throughput,
            100.0 * r.exposed_overhead / r.iter_time
        );
    }

    // Scaling with worker count on PBox.
    println!("\n=== PBox worker scaling (10G, {}) ===", dnn.abbrev);
    for n in [1usize, 2, 4, 8] {
        let c = ClusterConfig::paper_testbed()
            .with_net(NetConfig::cloud_10g())
            .with_workers(n);
        let r = sim::simulate(&c, &dnn, Gpu::Gtx1080Ti);
        println!("  {n} workers: {:>10.1} samples/s", r.throughput);
    }

    // Cross-rack: when is hierarchical reduction worth it?
    println!("\n=== hierarchical reduction across {racks} racks ===");
    let local = sim::simulate(
        &ClusterConfig::paper_testbed().with_net(NetConfig::cloud_10g()),
        &dnn,
        Gpu::Gtx1080Ti,
    );
    for r in 1..=racks {
        let tp = hierarchy::throughput_with_hierarchy(
            &dnn,
            r,
            8,
            local.iter_time,
            32 * 1024,
            10.0,
            10e-6,
        );
        println!(
            "  {r} racks ({} workers): {:>10.1} samples/s total, {:>8.1} per rack",
            8 * r,
            tp,
            tp / r as f64
        );
    }

    let bw = hierarchy::HierBandwidths {
        b_pbox: 12.5e9,
        b_core: 2.5e9,
        b_wkr: 1.25e9,
    };
    println!(
        "\nbenefit model: hierarchical beneficial at {racks} racks x 8 workers? {}",
        hierarchy::hierarchical_beneficial(bw, 8, racks)
    );
}
