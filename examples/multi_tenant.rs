//! Tenant guardrails on a live multi-tenant leader: admission control
//! with typed retriable refusals, weighted-fair scheduling shares,
//! idle eviction with parameter handoff, and bit-exact readmission —
//! narrated through the same `/jobs` status route an operator would
//! watch (see "Tenant guardrails" in `coordinator::transport`).
//!
//! The script: a leader capped at **two** concurrent jobs hosts tenants
//! A (weight 4) and B (weight 1). Tenant C's `Hello` is then *refused*
//! — a typed `Refused` frame with a reason and a retry-after hint, not
//! a hang — and C polls with `connect_with_backoff`. When B goes idle,
//! the janitor evicts it (staging params + optimizer state + round
//! positions as a handoff), which frees the seat C's next retry takes.
//! B later returns, readmits from the handoff (handoff readmission is
//! exempt from the job cap — eviction parked B's claim, it didn't
//! revoke it), resumes at its old round counter, and its next round is
//! bit-identical to a twin that was never evicted.
//!
//! Run: `cargo run --release --example multi_tenant`

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use phub::config::QuotaConfig;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::status::StatusServer;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::coordinator::Refusal;

const ROUNDS: usize = 3;

fn spec(model: u64) -> JobSpec {
    JobSpec {
        model_elems: model,
        chunk_elems: 512,
        n_workers: 1,
        lr: 0.05,
        momentum: 0.9,
    }
}

/// Deterministic per-round gradient, so B's resumed schedule can be
/// replayed bit-for-bit on the never-evicted twin leader.
fn grad(n: usize, r: usize) -> Vec<f32> {
    (0..n).map(|i| 0.1 * (r as f32 + 1.0) + (i % 7) as f32 * 0.01).collect()
}

/// Raw HTTP GET against the status endpoint — exactly what an operator
/// (or a Prometheus scraper) does; no client library involved.
fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("status connect");
    write!(s, "GET {path} HTTP/1.1\r\nHost: phub\r\nConnection: close\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).unwrap();
    match body.split_once("\r\n\r\n") {
        Some((_, b)) => b.to_string(),
        None => body,
    }
}

fn main() {
    let quota = QuotaConfig {
        max_jobs: 2,
        idle_evict_after: Some(Duration::from_millis(200)),
        weights: vec![(1, 4), (2, 1), (3, 1)],
        retry_after: Duration::from_millis(100),
        ..QuotaConfig::default()
    };
    let leader =
        TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2).with_quota(quota)).unwrap();
    let addr = leader.local_addr();
    let status = StatusServer::bind("127.0.0.1:0", leader.metrics_arc()).unwrap();
    let status_addr = status.local_addr();
    let jobs_view = |when: &str| {
        println!("--- /jobs {when}:\n    {}\n", http_get(status_addr, "/jobs"));
    };
    println!(
        "=== guardrailed leader on {addr}: max_jobs=2, idle_evict=200ms, \
         weights A:4 B:1 C:1 ===\n"
    );

    // A twin leader runs tenant B's exact schedule with no eviction —
    // the bit-identity reference for the readmission at the end.
    let twin = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let mut twin_b = TcpWorker::connect(twin.local_addr(), 2, spec(2048)).unwrap();

    // Step 1: tenants A and B fill the leader and train.
    let mut a = TcpWorker::connect(addr, 1, spec(4096)).unwrap();
    let mut b = TcpWorker::connect(addr, 2, spec(2048)).unwrap();
    let mut model_a = vec![0.0f32; 4096];
    let mut model_b = vec![0.0f32; 2048];
    let mut twin_model = vec![0.0f32; 2048];
    for r in 0..ROUNDS {
        a.push_pull_into(&grad(4096, r), &mut model_a).unwrap();
        b.push_pull_into(&grad(2048, r), &mut model_b).unwrap();
        twin_b.push_pull_into(&grad(2048, r), &mut twin_model).unwrap();
    }
    println!("[1] tenants A and B admitted, {ROUNDS} rounds each (leader full at max_jobs=2)");
    jobs_view("with A and B live");

    // Step 2: tenant C is over the job cap — refused, typed, retriable.
    let err = TcpWorker::connect(addr, 3, spec(1024)).unwrap_err();
    let refusal = err.downcast_ref::<Refusal>().expect("typed refusal");
    println!(
        "[2] tenant C refused: {refusal} (reason {:?}, retry-after {:?} — \
         a wire frame, not a dropped socket)",
        refusal.reason, refusal.retry_after
    );

    // Step 3: C keeps retrying on the hinted cadence while B goes idle;
    // the janitor evicts B (staging its handoff) and C's retry lands.
    let c_thread = std::thread::spawn(move || {
        let mut c = TcpWorker::connect_with_backoff(addr, 3, spec(1024), 200).unwrap();
        let mut m = vec![0.0f32; 1024];
        c.push_pull_into(&grad(1024, 0), &mut m).unwrap();
        c
    });
    b.bye();
    let metrics = leader.metrics_arc();
    let deadline = Instant::now() + Duration::from_secs(10);
    while metrics.snapshot().idle_evictions == 0 {
        assert!(Instant::now() < deadline, "idle eviction never fired");
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("[3] B idle 200ms with zero connections -> evicted with parameter handoff");
    jobs_view("after B's eviction (seat freed)");
    let c = c_thread.join().unwrap();
    println!("    C's backoff retry succeeded and trained a round");
    jobs_view("with A and C live");

    // Step 4: B returns. Readmission restores the handoff (params,
    // optimizer state, round counter) and is exempt from the job cap.
    let mut b = TcpWorker::connect(addr, 2, spec(2048)).unwrap();
    assert_eq!(b.rounds_done(), ROUNDS as u64, "B did not resume at its old round");
    b.push_pull_into(&grad(2048, ROUNDS), &mut model_b).unwrap();
    twin_b.push_pull_into(&grad(2048, ROUNDS), &mut twin_model).unwrap();
    let bit_exact = model_b
        .iter()
        .zip(twin_model.iter())
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(bit_exact, "readmitted tenant diverged from the never-evicted twin");
    println!(
        "[4] B readmitted at round {ROUNDS} and its round-{ROUNDS} output is \
         bit-exact vs a never-evicted twin: {bit_exact}"
    );
    jobs_view("after B's readmission");

    let snap = metrics.snapshot();
    println!(
        "guardrail counters: refused_job_cap={} refused_overload={} refused_quota={} \
         idle_evictions={} readmissions={} sched_deferrals={}",
        snap.refused_job_cap,
        snap.refused_overload,
        snap.refused_quota,
        snap.idle_evictions,
        snap.readmissions,
        snap.sched_deferrals
    );
    a.bye();
    b.bye();
    c.bye();
    twin_b.bye();
    status.shutdown();
}
