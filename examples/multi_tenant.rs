//! Multi-tenant PHub (paper section 4.8, Figure 18): several independent
//! training jobs share one PHub instance under isolated namespaces; this
//! example measures per-job throughput as the tenant count grows — for
//! real, on the live threaded server.
//!
//! Run: `cargo run --release --example multi_tenant -- [--model-kb 512]`

use phub::cli::Args;
use phub::coordinator::tenancy;

fn main() {
    let a = Args::from_env();
    let model_elems = a.get_usize("model-kb", 512) * 1024 / 4;
    let chunk = 8 * 1024; // 32 KB chunks
    let workers = a.get_usize("workers", 2);
    let rounds = a.get_usize("rounds", 20);
    let cores = a.get_usize("cores", 4);

    println!(
        "=== multi-tenant PHub: {} KB model, {} workers/job, {} cores ===\n",
        model_elems * 4 / 1024,
        workers,
        cores
    );
    println!(
        "{:>5} {:>16} {:>14} {:>18}",
        "jobs", "per-job exch/s", "fair share", "efficiency (xJ)"
    );
    let mut base = 0.0;
    for jobs in [1usize, 2, 4, 8] {
        let r = tenancy::run_concurrent_jobs(cores, jobs, workers, model_elems, chunk, rounds);
        let rate = r.mean_rate();
        if jobs == 1 {
            base = rate;
        }
        // J jobs timeshare this host's cores: fair share is 1/J of the
        // solo rate; "efficiency" isolates PHub-induced interference from
        // the unavoidable timeshare (the quantity Figure 18 reports).
        println!(
            "{:>5} {:>16.2} {:>13.0}% {:>17.0}%",
            jobs,
            rate,
            100.0 * rate / base,
            100.0 * rate * jobs as f64 / base
        );
    }
    println!(
        "\n(compare Figure 18: per-job efficiency stays within ~5% for\n \
         compute-bound models; exchange-bound models degrade more)"
    );
}
