//! Flight-recorder walkthrough: train a few rounds over TCP with the
//! recorder on, dump the captured spans as chrome://tracing JSON, and
//! print a per-stage time breakdown of where a round actually goes —
//! the paper's §4.1-style decomposition (network / aggregation /
//! optimization / sync) measured on this implementation's own stage
//! boundaries instead of estimated.
//!
//! Open the JSON in `chrome://tracing` or https://ui.perfetto.dev to
//! see frame reads, absorbs, fused optimize passes, reply encodes and
//! socket writes laid out per thread on one timeline.
//!
//! Run: `cargo run --release --example traced_round -- \
//!        [--workers 2] [--rounds 20] [--out trace.json]`

use std::collections::BTreeMap;

use phub::cli::Args;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::trace;

fn main() -> anyhow::Result<()> {
    let a = Args::from_env();
    let workers = a.get_usize("workers", 2) as u32;
    let model = a.get_usize("model-kb", 256) * 1024 / 4;
    let rounds = a.get_usize("rounds", 20);
    let out = a.get_or("out", "trace.json").to_string();

    if !trace::enabled() {
        println!("note: recorder disabled (built without the `trace` feature?)");
    }

    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2))?;
    let addr = leader.local_addr();
    let spec = JobSpec {
        model_elems: model as u64,
        chunk_elems: 8192,
        n_workers: workers,
        lr: 0.1,
        momentum: 0.9,
    };
    println!(
        "leader on {addr}, {workers} workers, {} KB model, {rounds} rounds",
        model * 4 / 1024
    );

    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..workers)
        .map(|w| {
            std::thread::spawn(move || -> anyhow::Result<()> {
                let mut worker = TcpWorker::connect(addr, 1, spec)?;
                let grad: Vec<f32> =
                    (0..model).map(|i| ((i + w as usize) % 7) as f32 * 0.1).collect();
                let mut m = vec![0.0f32; model];
                for _ in 0..rounds {
                    worker.push_pull_into(&grad, &mut m)?;
                }
                worker.bye();
                Ok(())
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap()?;
    }
    let wall = t0.elapsed();

    // Dump everything the per-thread rings still hold, then break the
    // span time down by stage.
    let events = trace::snapshot();
    std::fs::write(&out, trace::chrome_trace_json(&events))?;
    println!("{} events -> {out} (open in chrome://tracing)", events.len());

    let mut by_stage: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
    for ev in &events {
        let e = by_stage.entry(ev.stage.name()).or_insert((0, 0));
        e.0 += 1;
        e.1 += ev.dur_ns;
    }
    let total_ns: u64 = by_stage.values().map(|&(_, ns)| ns).sum();
    println!(
        "\n  {:<16} {:>8} {:>12} {:>10} {:>7}",
        "stage", "events", "total µs", "mean µs", "share"
    );
    for (name, (n, ns)) in &by_stage {
        println!(
            "  {name:<16} {n:>8} {:>12.1} {:>10.2} {:>6.1}%",
            *ns as f64 / 1e3,
            *ns as f64 / 1e3 / *n as f64,
            *ns as f64 / total_ns.max(1) as f64 * 100.0
        );
    }
    println!(
        "\n  {rounds} rounds in {:.2}s ({:.1} rounds/s); recorded span time {:.1} ms",
        wall.as_secs_f64(),
        rounds as f64 / wall.as_secs_f64(),
        total_ns as f64 / 1e6
    );
    println!("traced_round OK");
    Ok(())
}
