//! Quickstart: the PHub public API in ~60 lines.
//!
//! Creates a PHub server, registers a job through the paper's service API
//! (CreateService → InitService → ConnectService), runs a few synchronous
//! push_pull rounds from four worker threads, and checks the update math
//! against a sequential reference.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use phub::coordinator::server::ServerConfig;
use phub::coordinator::{ConnectionManager, KeyTable, NesterovSgd, Optimizer, PHubServer};

fn main() {
    const WORKERS: usize = 4;
    const MODEL: usize = 64 * 1024; // elements
    const CHUNK: usize = 8 * 1024; // = PHub's 32 KB wire chunks
    const ROUNDS: usize = 10;

    // 1. Start a PHub instance with 4 aggregation cores.
    let server = PHubServer::start(ServerConfig::cores(4));
    let cm = ConnectionManager::new(server.clone());

    // 2. Create + initialize the job namespace.
    let svc = cm.create_service("quickstart", WORKERS).expect("namespace");
    let opt = NesterovSgd {
        lr: 0.1,
        momentum: 0.9,
    };
    let init = vec![0.5f32; MODEL];
    cm.init_service(
        &svc,
        KeyTable::flat(MODEL, CHUNK),
        &init,
        Arc::new(opt.clone()),
    )
    .expect("init");

    // 3. Connect workers and run synchronous rounds.
    let mut handles: Vec<_> = (0..WORKERS)
        .map(|w| cm.connect_service(&svc, w).expect("connect"))
        .collect();

    let grad_for = |w: usize, r: usize| -> Vec<f32> {
        (0..MODEL)
            .map(|i| ((w + r) as f32).sin() * 0.01 + (i % 7) as f32 * 1e-4)
            .collect()
    };

    let mut final_model = Vec::new();
    for r in 0..ROUNDS {
        let models: Vec<Vec<f32>> = std::thread::scope(|s| {
            let joins: Vec<_> = handles
                .iter_mut()
                .enumerate()
                .map(|(w, h)| {
                    let g = grad_for(w, r);
                    s.spawn(move || h.push_pull(&g))
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert!(models.windows(2).all(|m| m[0] == m[1]), "workers agree");
        final_model = models.into_iter().next().unwrap();
        println!("round {r}: model[0] = {:.6}", final_model[0]);
    }

    // 4. Verify against the sequential reference.
    let mut p = vec![0.5f32; MODEL];
    let mut m = vec![0.0f32; MODEL];
    for r in 0..ROUNDS {
        let mut mean = vec![0.0f32; MODEL];
        for w in 0..WORKERS {
            for (a, g) in mean.iter_mut().zip(grad_for(w, r)) {
                *a += g / WORKERS as f32;
            }
        }
        opt.step(&mut p, &mut m, &mean);
    }
    let max_err = final_model
        .iter()
        .zip(&p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |phub - reference| = {max_err:e}");
    assert!(max_err < 1e-5);

    PHubServer::shutdown(server);
    println!("quickstart OK");
}
