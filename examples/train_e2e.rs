//! End-to-end training: the full three-layer stack on a real workload.
//!
//! Workers execute the AOT-compiled JAX transformer (`grad_step.hlo.txt`,
//! produced by `make artifacts`) via PJRT; gradients are exchanged through
//! the live PHub server (tall aggregation + Nesterov, matching the L1
//! Pallas kernel's math); the loss curve on a synthetic byte-level corpus
//! is logged. The recorded run is in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e -- \
//!        [--workers 4] [--steps 200] [--lr 0.05]`

use phub::cli::Args;
use phub::e2e;
use phub::runtime;

fn main() -> anyhow::Result<()> {
    let a = Args::from_env();
    let artifacts = runtime::default_artifacts_dir();
    let workers = a.get_usize("workers", 4);
    let steps = a.get_usize("steps", 200);
    let cores = a.get_usize("cores", 4);
    let lr = a.get_f64("lr", 0.05) as f32;
    let mu = a.get_f64("momentum", 0.9) as f32;

    println!("artifacts: {artifacts:?}");
    let report = e2e::train(&artifacts, workers, steps, cores, lr, mu, true)?;

    let (head, tail) = report.mean_loss_head_tail(10);
    println!("\n=== train_e2e report ===");
    println!("model params     : {}", report.param_count);
    println!("workers x steps  : {} x {}", report.workers, report.steps);
    println!("loss (first 10)  : {head:.4}");
    println!("loss (last 10)   : {tail:.4}");
    println!("throughput       : {:.1} samples/s", report.samples_per_sec);
    println!("exchange rate    : {:.2} /s", report.exchanges_per_sec);
    anyhow::ensure!(tail < head, "loss did not decrease: {head} -> {tail}");
    println!("loss decreased: OK");
    Ok(())
}
