//! Distributed PHub over TCP: a leader process serving workers through the
//! wire protocol, with dense and 2-bit-compressed exchange paths at both
//! protocol versions (v1 chunk-streamed, v0 monolithic).
//!
//! Spawns the leader and N worker clients (threads here; the same code
//! works across processes/machines — see `phub::coordinator::transport`),
//! runs synchronous rounds for every (protocol x compression) combination,
//! and reports wire bytes and round throughput for each. The streamed
//! protocol is the paper's §3.2 data plane shape: chunk frames routed to
//! pinned cores as they arrive, per-chunk model replies overlapping later
//! chunks' aggregation. The compressed path demonstrates the section 5
//! claim: PHub composes with gradient compression (~16x less push
//! traffic) without touching the aggregation engine.
//!
//! Run: `cargo run --release --example distributed_tcp -- [--workers 4]`

use phub::cli::Args;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::coordinator::wire;

fn main() -> anyhow::Result<()> {
    let a = Args::from_env();
    let workers = a.get_usize("workers", 4) as u32;
    let model = a.get_usize("model-kb", 1024) * 1024 / 4;
    let rounds = a.get_usize("rounds", 10);

    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 4 })?;
    let addr = leader.local_addr();
    println!(
        "leader on {addr}, {workers} workers, {} KB model",
        model * 4 / 1024
    );

    let mut job = 0u32;
    for (plabel, proto) in [
        ("streamed v1", wire::PROTO_CHUNK_STREAMED),
        ("monolithic v0", wire::PROTO_MONOLITHIC),
    ] {
        for (label, quant) in [("dense f32", false), ("2-bit compressed", true)] {
            job += 1;
            let chunk_elems = 8192usize;
            let spec = JobSpec {
                model_elems: model as u64,
                chunk_elems: chunk_elems as u64,
                n_workers: workers,
                lr: 0.1,
                momentum: 0.9,
            };
            // Exact per-round push bytes on the wire, per protocol: v0 is
            // one frame (16 B header) for the whole model; v1 is one frame
            // per chunk, each with the 12 B chunk prefix (and the 12 B
            // QuantGrad header per segment on the compressed path).
            let chunk_lens: Vec<usize> = (0..model)
                .step_by(chunk_elems)
                .map(|o| chunk_elems.min(model - o))
                .collect();
            let round_bytes: usize = if proto == wire::PROTO_CHUNK_STREAMED {
                chunk_lens
                    .iter()
                    .map(|&l| 16 + 12 + if quant { 12 + l.div_ceil(4) } else { l * 4 })
                    .sum()
            } else if quant {
                16 + 12 + model.div_ceil(4)
            } else {
                16 + model * 4
            };
            let t0 = std::time::Instant::now();
            let joins: Vec<_> = (0..workers)
                .map(|w| {
                    std::thread::spawn(move || -> anyhow::Result<(Vec<f32>, usize)> {
                        let mut worker = TcpWorker::connect_with_proto(addr, job, spec, proto)?;
                        assert_eq!(worker.proto(), proto, "negotiation");
                        let grad: Vec<f32> = (0..model)
                            .map(|i| ((i + w as usize) % 13) as f32 * 0.01)
                            .collect();
                        let mut m = Vec::new();
                        let mut wire_bytes = 0usize;
                        for _ in 0..rounds {
                            wire_bytes += round_bytes;
                            if quant {
                                m = worker.push_pull_quant(&grad, 0.05)?;
                            } else {
                                m = worker.push_pull(&grad)?;
                            }
                        }
                        worker.bye();
                        Ok((m, wire_bytes))
                    })
                })
                .collect();
            let mut final_models = Vec::new();
            let mut push_bytes = 0usize;
            for j in joins {
                let (m, b) = j.join().unwrap()?;
                final_models.push(m);
                push_bytes += b;
            }
            let dt = t0.elapsed().as_secs_f64();
            assert!(
                final_models.windows(2).all(|w| w[0] == w[1]),
                "synchronous workers must agree"
            );
            println!(
                "  {plabel:<14} {label:<18} {rounds} rounds in {dt:.2}s ({:.1} rounds/s), \
                 push traffic {:.1} MB, model[0..2]={:?}",
                rounds as f64 / dt,
                push_bytes as f64 / 1e6,
                &final_models[0][..2]
            );
        }
    }
    println!("distributed_tcp OK");
    Ok(())
}
