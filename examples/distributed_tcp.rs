//! Distributed PHub over TCP: a leader process serving workers through the
//! wire protocol, with dense and 2-bit-compressed exchange paths.
//!
//! Spawns the leader and N worker clients (threads here; the same code
//! works across processes/machines — see `phub::coordinator::transport`),
//! runs synchronous rounds both dense and compressed, and reports wire
//! bytes and round throughput for each. The compressed path demonstrates
//! the paper's section 5 claim: PHub composes with gradient compression
//! (~16x less push traffic) without touching the aggregation engine.
//!
//! Run: `cargo run --release --example distributed_tcp -- [--workers 4]`

use phub::cli::Args;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};

fn main() -> anyhow::Result<()> {
    let a = Args::from_env();
    let workers = a.get_usize("workers", 4) as u32;
    let model = a.get_usize("model-kb", 1024) * 1024 / 4;
    let rounds = a.get_usize("rounds", 10);

    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 4 })?;
    let addr = leader.local_addr();
    println!("leader on {addr}, {workers} workers, {} KB model", model * 4 / 1024);

    for (label, quant) in [("dense f32", false), ("2-bit compressed", true)] {
        let job = if quant { 2 } else { 1 };
        let spec = JobSpec {
            model_elems: model as u64,
            chunk_elems: 8192,
            n_workers: workers,
            lr: 0.1,
            momentum: 0.9,
        };
        let t0 = std::time::Instant::now();
        let joins: Vec<_> = (0..workers)
            .map(|w| {
                std::thread::spawn(move || -> anyhow::Result<(Vec<f32>, usize)> {
                    let mut worker = TcpWorker::connect(addr, job, spec)?;
                    let grad: Vec<f32> =
                        (0..model).map(|i| ((i + w as usize) % 13) as f32 * 0.01).collect();
                    let mut m = Vec::new();
                    let mut wire_bytes = 0usize;
                    for _ in 0..rounds {
                        if quant {
                            wire_bytes += model / 4 + 12; // packed levels
                            m = worker.push_pull_quant(&grad, 0.05)?;
                        } else {
                            wire_bytes += model * 4;
                            m = worker.push_pull(&grad)?;
                        }
                    }
                    worker.bye();
                    Ok((m, wire_bytes))
                })
            })
            .collect();
        let mut final_models = Vec::new();
        let mut push_bytes = 0usize;
        for j in joins {
            let (m, b) = j.join().unwrap()?;
            final_models.push(m);
            push_bytes += b;
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(
            final_models.windows(2).all(|w| w[0] == w[1]),
            "synchronous workers must agree"
        );
        println!(
            "  {label:<18} {rounds} rounds in {dt:.2}s ({:.1} rounds/s), \
             push traffic {:.1} MB, model[0..2]={:?}",
            rounds as f64 / dt,
            push_bytes as f64 / 1e6,
            &final_models[0][..2]
        );
    }
    println!("distributed_tcp OK");
    Ok(())
}
