//! Distributed PHub over TCP: a leader process serving workers through the
//! chunk-streamed wire protocol, with dense and 2-bit-compressed exchange
//! paths.
//!
//! Spawns the leader and N worker clients (threads here; the same code
//! works across processes/machines — see `phub::coordinator::transport`),
//! runs synchronous rounds for every (chunking x compression) combination,
//! and reports wire bytes and round throughput for each. The streamed
//! protocol is the paper's §3.2 data plane shape: chunk frames routed to
//! pinned cores as they arrive, per-chunk model replies overlapping later
//! chunks' aggregation — the single-chunk row shows what the retired v0
//! monolithic protocol used to cost (one serialized frame each way). The
//! compressed path demonstrates the section 5 claim: PHub composes with
//! gradient compression (~16x less push traffic) without touching the
//! round engine.
//!
//! (Wire protocol v0 — whole-model `PushPull`/`Model` frames — was retired
//! this release; a v0 `Hello` is now rejected at rendezvous with a clear
//! error. See `wire.rs`.)
//!
//! Run: `cargo run --release --example distributed_tcp -- [--workers 4]`

use phub::cli::Args;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};

fn main() -> anyhow::Result<()> {
    let a = Args::from_env();
    let workers = a.get_usize("workers", 4) as u32;
    let model = a.get_usize("model-kb", 1024) * 1024 / 4;
    let rounds = a.get_usize("rounds", 10);

    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(4))?;
    let addr = leader.local_addr();
    println!(
        "leader on {addr}, {workers} workers, {} KB model",
        model * 4 / 1024
    );

    let mut job = 0u32;
    for (clabel, chunk_elems) in [
        ("streamed 32KB chunks", 8192usize),
        ("single chunk (v0-shaped)", model),
    ] {
        for (label, quant) in [("dense f32", false), ("2-bit compressed", true)] {
            job += 1;
            let spec = JobSpec {
                model_elems: model as u64,
                chunk_elems: chunk_elems as u64,
                n_workers: workers,
                lr: 0.1,
                momentum: 0.9,
            };
            // Exact per-round push bytes on the wire: one frame per chunk,
            // each with the 16 B frame header and 16 B chunk prefix (and
            // the 12 B QuantGrad header per segment when compressed).
            let chunk_lens: Vec<usize> = (0..model)
                .step_by(chunk_elems)
                .map(|o| chunk_elems.min(model - o))
                .collect();
            let round_bytes: usize = chunk_lens
                .iter()
                .map(|&l| 16 + 16 + if quant { 12 + l.div_ceil(4) } else { l * 4 })
                .sum();
            let t0 = std::time::Instant::now();
            let joins: Vec<_> = (0..workers)
                .map(|w| {
                    std::thread::spawn(move || -> anyhow::Result<(Vec<f32>, usize)> {
                        let mut worker = TcpWorker::connect(addr, job, spec)?;
                        let grad: Vec<f32> = (0..model)
                            .map(|i| ((i + w as usize) % 13) as f32 * 0.01)
                            .collect();
                        let mut m = Vec::new();
                        let mut wire_bytes = 0usize;
                        for _ in 0..rounds {
                            wire_bytes += round_bytes;
                            if quant {
                                m = worker.push_pull_quant(&grad, 0.05)?;
                            } else {
                                m = worker.push_pull(&grad)?;
                            }
                        }
                        worker.bye();
                        Ok((m, wire_bytes))
                    })
                })
                .collect();
            let mut final_models = Vec::new();
            let mut push_bytes = 0usize;
            for j in joins {
                let (m, b) = j.join().unwrap()?;
                final_models.push(m);
                push_bytes += b;
            }
            let dt = t0.elapsed().as_secs_f64();
            assert!(
                final_models.windows(2).all(|w| w[0] == w[1]),
                "synchronous workers must agree"
            );
            println!(
                "  {clabel:<24} {label:<18} {rounds} rounds in {dt:.2}s ({:.1} rounds/s), \
                 push traffic {:.1} MB, model[0..2]={:?}",
                rounds as f64 / dt,
                push_bytes as f64 / 1e6,
                &final_models[0][..2]
            );
        }
    }
    println!("distributed_tcp OK");
    Ok(())
}
