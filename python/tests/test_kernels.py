"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal for the compile path: every kernel must match
ref.py across a hypothesis-swept space of shapes, worker counts, chunk
sizes, and hyperparameters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.agg_opt import agg_only, agg_opt
from compile.kernels.quant import quant2bit
from compile.kernels.ref import agg_only_ref, agg_opt_ref, quant2bit_ref

settings.register_profile("kernels", max_examples=20, deadline=None)
settings.load_profile("kernels")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# agg_opt: fused aggregation + Nesterov SGD
# ---------------------------------------------------------------------------


@given(
    workers=st.integers(1, 9),
    chunks=st.integers(1, 5),
    chunk=st.sampled_from([128, 256, 1024]),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
)
def test_agg_opt_matches_ref(workers, chunks, chunk, lr, mu):
    k = chunks * chunk
    g = rand(1, workers, k)
    p = rand(2, k)
    m = rand(3, k) * 0.1
    got_p, got_m = agg_opt(g, p, m, lr, mu, chunk=chunk)
    ref_p, ref_m = agg_opt_ref(g, p, m, lr, mu)
    np.testing.assert_allclose(got_p, ref_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, ref_m, rtol=1e-5, atol=1e-6)


def test_agg_opt_zero_momentum_is_sgd():
    k = 512
    g = rand(1, 4, k)
    p = rand(2, k)
    m = jnp.zeros((k,))
    got_p, got_m = agg_opt(g, p, m, 0.5, 0.0, chunk=256)
    mean = jnp.mean(g, axis=0)
    np.testing.assert_allclose(got_p, p - 0.5 * mean, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, mean, rtol=1e-5, atol=1e-6)


def test_agg_opt_rejects_misaligned():
    g = rand(1, 2, 100)
    p = rand(2, 100)
    m = jnp.zeros((100,))
    with pytest.raises(ValueError, match="multiple of chunk"):
        agg_opt(g, p, m, 0.1, 0.9, chunk=64)


def test_agg_opt_multi_step_trajectory():
    """Three PS rounds through the kernel equal three reference rounds."""
    k, w = 256, 3
    p_k = p_r = rand(0, k)
    m_k = m_r = jnp.zeros((k,))
    for step in range(3):
        g = rand(10 + step, w, k)
        p_k, m_k = agg_opt(g, p_k, m_k, 0.1, 0.9, chunk=128)
        p_r, m_r = agg_opt_ref(g, p_r, m_r, 0.1, 0.9)
    np.testing.assert_allclose(p_k, p_r, rtol=1e-4, atol=1e-5)


def test_agg_opt_under_jit():
    k = 8192
    g, p, m = rand(1, 2, k), rand(2, k), jnp.zeros((k,))
    f = jax.jit(lambda g, p, m: agg_opt(g, p, m, 0.1, 0.9))
    got_p, _ = f(g, p, m)
    ref_p, _ = agg_opt_ref(g, p, m, 0.1, 0.9)
    np.testing.assert_allclose(got_p, ref_p, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# agg_only (hierarchical reduction path)
# ---------------------------------------------------------------------------


@given(workers=st.integers(1, 8), chunks=st.integers(1, 4))
def test_agg_only_matches_ref(workers, chunks):
    k = chunks * 256
    g = rand(5, workers, k)
    np.testing.assert_allclose(
        agg_only(g, chunk=256), agg_only_ref(g), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# quant2bit: 2-bit gradient compression with error feedback
# ---------------------------------------------------------------------------


@given(
    chunks=st.integers(1, 4),
    threshold=st.floats(0.05, 2.0),
)
def test_quant_matches_ref(chunks, threshold):
    k = chunks * 256
    g = rand(7, k)
    r = rand(8, k) * 0.1
    q1, nr1, dq1 = quant2bit(g, r, threshold, chunk=256)
    q2, nr2, dq2 = quant2bit_ref(g, r, threshold)
    np.testing.assert_allclose(q1, q2)
    np.testing.assert_allclose(nr1, nr2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dq1, dq2, rtol=1e-5, atol=1e-6)


@given(threshold=st.floats(0.1, 1.0))
def test_quant_levels_are_two_bit(threshold):
    g = rand(9, 512)
    q, _, _ = quant2bit(g, jnp.zeros((512,)), threshold, chunk=256)
    assert set(np.unique(np.asarray(q))) <= {-1.0, 0.0, 1.0}


def test_quant_error_feedback_conserves_signal():
    """dequant + new_residual == grad + residual (nothing is lost)."""
    g = rand(11, 512)
    r = rand(12, 512) * 0.3
    q, nr, dq = quant2bit(g, r, 0.5, chunk=256)
    np.testing.assert_allclose(
        np.asarray(dq) + np.asarray(nr), np.asarray(g) + np.asarray(r),
        rtol=1e-5, atol=1e-6,
    )


def test_quant_residual_bounded_by_threshold():
    """After quantization the carried error is < threshold wherever the
    input magnitude was <= 2*threshold (the quantizer's contract)."""
    t = 0.5
    g = jnp.clip(rand(13, 512), -2 * t, 2 * t)
    _, nr, _ = quant2bit(g, jnp.zeros((512,)), t, chunk=256)
    assert np.max(np.abs(np.asarray(nr))) <= t + 1e-6


def test_quant_accumulated_rounds_converge():
    """Error feedback over many rounds: the quantized stream's running sum
    tracks the true gradient sum (classic EF-SGD property)."""
    k = 256
    true_sum = np.zeros(k, np.float32)
    dq_sum = np.zeros(k, np.float32)
    r = jnp.zeros((k,))
    for step in range(30):
        g = rand(100 + step, k) * 0.2
        _, r, dq = quant2bit(g, r, 0.5, chunk=256)
        true_sum += np.asarray(g)
        dq_sum += np.asarray(dq)
    # Residual bound: |sum dq - sum g| = |final residual| <= threshold-ish.
    assert np.max(np.abs(dq_sum - true_sum)) <= 0.5 + 1e-5
