"""AOT path tests: the HLO-text lowering used by the Rust runtime."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M

CFG = M.ModelConfig(vocab=32, d_model=16, n_layers=1, n_heads=2, d_ff=32, seq_len=8, batch=2)


def test_to_hlo_text_structure():
    lowered = jax.jit(M.make_eval_loss(CFG)).lower(
        jax.ShapeDtypeStruct((M.padded_size(CFG),), jnp.float32),
        jax.ShapeDtypeStruct((CFG.batch, CFG.seq_len + 1), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    # HLO text essentials the Rust-side parser depends on.
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple.
    assert "tuple(" in text or "(f32[]" in text


def test_lower_all_writes_artifacts(tmp_path: pathlib.Path):
    man = aot.lower_all(CFG, n_workers=2, out_dir=tmp_path)
    for name in ["grad_step", "eval_loss", "agg_opt", "agg_only", "quant2bit"]:
        p = tmp_path / f"{name}.hlo.txt"
        assert p.exists() and p.stat().st_size > 100, name
        assert "HloModule" in p.read_text()[:200]
    params = np.fromfile(tmp_path / "params_init.bin", dtype=np.float32)
    assert params.shape[0] == man["padded_size"]
    assert man["param_count"] == M.param_count(CFG)
    # Manifest JSON parses and matches.
    import json

    j = json.loads((tmp_path / "manifest.json").read_text())
    assert j["padded_size"] == man["padded_size"]
    assert j["n_workers"] == 2
    assert len(j["keys"]) == len(M.key_table(CFG))


def test_pallas_kernel_lowering_contains_no_custom_call(tmp_path: pathlib.Path):
    """interpret=True must lower the Pallas kernel to plain HLO — a Mosaic
    custom-call would be unrunnable on the CPU PJRT client."""
    from compile.kernels.agg_opt import agg_opt

    k = M.padded_size(CFG)
    lowered = jax.jit(lambda g, p, m, lr, mu: agg_opt(g, p, m, lr, mu)).lower(
        jax.ShapeDtypeStruct((2, k), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_artifact_numerics_sane(tmp_path: pathlib.Path):
    """The lowered text's metadata matches the jax-side function, and the
    jax-side value is sane. (Executing the *text* through PJRT is covered
    end-to-end on the Rust side in rust/tests/runtime_integration.rs —
    that is the actual interchange contract.)"""
    lowered = jax.jit(M.make_eval_loss(CFG)).lower(
        jax.ShapeDtypeStruct((M.padded_size(CFG),), jnp.float32),
        jax.ShapeDtypeStruct((CFG.batch, CFG.seq_len + 1), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    # Two parameters, f32 model vector of the right padded size.
    assert f"f32[{M.padded_size(CFG)}]" in text
    assert f"s32[{CFG.batch},{CFG.seq_len + 1}]" in text
    params = M.flatten_params(CFG, M.init_params(CFG))
    toks = jax.random.randint(jax.random.PRNGKey(0), (CFG.batch, CFG.seq_len + 1), 0, CFG.vocab)
    (expected,) = M.make_eval_loss(CFG)(params, toks)
    assert np.isfinite(float(expected))
