"""L2 model tests: shapes, flat/pytree bijection, gradient sanity,
single-process training convergence, key-table consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.agg_opt import CHUNK_ELEMS
from compile.kernels.ref import agg_opt_ref

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=4)


def tokens(key, cfg=CFG):
    return jax.random.randint(jax.random.PRNGKey(key), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)


def test_param_count_matches_key_table():
    table = M.key_table(CFG)
    total = sum(e["len"] for e in table)
    assert total == M.param_count(CFG)
    # Offsets are contiguous.
    off = 0
    for e in table:
        assert e["offset"] == off
        off += e["len"]
        assert int(np.prod(e["shape"])) == e["len"]


def test_padded_size_is_chunk_multiple():
    k = M.padded_size(CFG)
    assert k % CHUNK_ELEMS == 0
    assert k >= M.param_count(CFG)


def test_flatten_roundtrip():
    params = M.init_params(CFG, seed=3)
    flat = M.flatten_params(CFG, params)
    unflatten = M._unflattener(CFG)
    rebuilt = unflatten(flat)
    for path_leaf, orig_leaf in zip(
        jax.tree_util.tree_leaves(rebuilt), jax.tree_util.tree_leaves(params)
    ):
        np.testing.assert_array_equal(path_leaf, orig_leaf)
    # Pad region is zero.
    p = M.param_count(CFG)
    assert np.all(np.asarray(flat[p:]) == 0.0)


def test_forward_shapes_and_loss_at_init():
    params = M.init_params(CFG)
    toks = tokens(0)
    logits = M.forward(CFG, params, toks[:, :-1])
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    loss = M.loss_fn(CFG, params, toks)
    # Near-uniform prediction at init: loss ~ ln(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_grad_step_gradients_finite_and_pad_zero():
    gs = jax.jit(M.make_grad_step(CFG))
    pf = M.flatten_params(CFG, M.init_params(CFG))
    loss, g = gs(pf, tokens(1))
    assert np.isfinite(float(loss))
    g = np.asarray(g)
    assert np.all(np.isfinite(g))
    assert np.linalg.norm(g) > 1e-5
    assert np.all(g[M.param_count(CFG):] == 0.0)


def test_eval_loss_matches_grad_step():
    gs = jax.jit(M.make_grad_step(CFG))
    ev = jax.jit(M.make_eval_loss(CFG))
    pf = M.flatten_params(CFG, M.init_params(CFG))
    toks = tokens(2)
    l1, _ = gs(pf, toks)
    (l2,) = ev(pf, toks)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_training_reduces_loss_via_kernel_optimizer():
    """Mini data-parallel training: W=2 workers, the agg_opt kernel as the
    PS. Loss on a fixed pattern decreases."""
    cfg = CFG
    gs = jax.jit(M.make_grad_step(cfg))
    step = jax.jit(M.make_agg_opt(cfg, 2))
    k = M.padded_size(cfg)
    pf = M.flatten_params(cfg, M.init_params(cfg))
    mom = jnp.zeros((k,))
    # Learnable pattern: arithmetic token ramps.
    def batch(seed):
        start = jax.random.randint(jax.random.PRNGKey(seed), (cfg.batch, 1), 0, cfg.vocab)
        ramp = jnp.arange(cfg.seq_len + 1)[None, :]
        return (start + ramp) % cfg.vocab

    losses = []
    for i in range(12):
        grads = []
        loss_sum = 0.0
        for w in range(2):
            loss, g = gs(pf, batch(100 + 2 * i + w))
            grads.append(g)
            loss_sum += float(loss)
        losses.append(loss_sum / 2)
        pf, mom = step(jnp.stack(grads), pf, mom, 0.3, 0.9)
    assert losses[-1] < losses[0] - 0.2, losses


def test_agg_opt_step_equals_manual_reference():
    cfg = CFG
    k = M.padded_size(cfg)
    step = M.make_agg_opt(cfg, 3)
    g = jax.random.normal(jax.random.PRNGKey(5), (3, k))
    p = jax.random.normal(jax.random.PRNGKey(6), (k,))
    m = jnp.zeros((k,))
    got_p, got_m = step(g, p, m, 0.1, 0.9)
    ref_p, ref_m = agg_opt_ref(g, p, m, 0.1, 0.9)
    np.testing.assert_allclose(got_p, ref_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, ref_m, rtol=1e-5, atol=1e-6)


def test_manifest_contents():
    man = M.manifest(CFG, n_workers=4)
    assert man["param_count"] == M.param_count(CFG)
    assert man["padded_size"] == M.padded_size(CFG)
    assert man["chunk_elems"] == CHUNK_ELEMS
    assert man["n_workers"] == 4
    assert len(man["keys"]) == len(M.key_table(CFG))
    # JSON-serializable.
    import json

    parsed = json.loads(M.manifest_json(CFG, 4))
    assert parsed["param_count"] == man["param_count"]


def test_causality():
    """Changing a future token must not affect earlier logits."""
    params = M.init_params(CFG)
    toks = np.asarray(tokens(9)[:, :-1])
    logits1 = M.forward(CFG, params, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
    logits2 = M.forward(CFG, params, jnp.asarray(toks2))
    np.testing.assert_allclose(
        logits1[:, :-1], logits2[:, :-1], rtol=1e-5, atol=1e-6
    )
    assert not np.allclose(logits1[:, -1], logits2[:, -1])


@pytest.mark.parametrize("n_heads", [1, 2, 4])
def test_head_count_variants(n_heads):
    cfg = M.ModelConfig(vocab=32, d_model=32, n_layers=1, n_heads=n_heads, d_ff=32, seq_len=8, batch=2)
    loss = M.loss_fn(cfg, M.init_params(cfg), tokens(11, cfg))
    assert np.isfinite(float(loss))
