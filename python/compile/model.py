"""L2: JAX transformer LM forward/backward — the worker compute that feeds PHub.

This is the "worker" half of the paper's training loop (Figure 3): each
worker runs forward+backward on its minibatch and hands a flattened gradient
vector to the parameter server. The PS half is the agg_opt Pallas kernel.

The public compute graphs, all AOT-lowered by aot.py to HLO text and
executed from Rust via PJRT:

  grad_step(params_flat, tokens)  -> (loss, grads_flat)
  eval_loss(params_flat, tokens)  -> (loss,)
  agg_opt_step(grads, params, mom, lr, mu) -> (params', mom')   [L1 kernel]

The model is deliberately parameterized only by a small config so artifact
sizes stay CPU-tractable; the layer/key table (name, offset, length) is
exported so the Rust coordinator can chunk and shard *per layer*, exactly as
a PS shards "keys" (paper section 2: key = layer, value = its parameters).

Everything operates on a single flat f32 vector padded to a multiple of the
PHub chunk size, so the Rust side owns exactly one contiguous model buffer —
mirroring PHub's one-shot NUMA-aware registration of a single contiguous
block (section 3.2.1).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from .kernels.agg_opt import CHUNK_ELEMS


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer LM hyperparameters (byte-level vocabulary by default)."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 8

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Parameter construction and the flat <-> pytree bijection
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, Any]:
    """Initialize the parameter pytree with scaled-normal weights."""
    k = jax.random.PRNGKey(seed)
    keys = jax.random.split(k, 2 + 6 * cfg.n_layers)
    it = iter(keys)

    def dense(key, fan_in, fan_out):
        return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * (fan_in**-0.5)

    params: dict[str, Any] = {
        "embed": jax.random.normal(next(it), (cfg.vocab, cfg.d_model)) * 0.02,
        "pos": jax.random.normal(next(it), (cfg.seq_len, cfg.d_model)) * 0.02,
    }
    for i in range(cfg.n_layers):
        params[f"blk{i}"] = {
            "wqkv": dense(next(it), cfg.d_model, 3 * cfg.d_model),
            "wo": dense(next(it), cfg.d_model, cfg.d_model),
            "w1": dense(next(it), cfg.d_model, cfg.d_ff),
            "w2": dense(next(it), cfg.d_ff, cfg.d_model),
            "ln1": jnp.ones((cfg.d_model,)),
            "ln2": jnp.ones((cfg.d_model,)),
        }
    params["lnf"] = jnp.ones((cfg.d_model,))
    # Output head is tied to the embedding (standard weight tying).
    return params


def key_table(cfg: ModelConfig) -> list[dict[str, Any]]:
    """Exported layer/key table: (name, offset, length) in flat order.

    This is what the Rust coordinator treats as PS "keys". Offsets are into
    the *unpadded* flat vector; the order matches ravel_pytree's canonical
    (sorted-dict) traversal.
    """
    params = init_params(cfg)
    leaves_with_path = jax.tree_util.tree_leaves_with_path(params)
    table = []
    off = 0
    for path, leaf in leaves_with_path:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        n = int(np.prod(leaf.shape))
        table.append({"name": name, "offset": off, "len": n, "shape": list(leaf.shape)})
        off += n
    return table


def param_count(cfg: ModelConfig) -> int:
    return sum(e["len"] for e in key_table(cfg))


def padded_size(cfg: ModelConfig, chunk: int = CHUNK_ELEMS) -> int:
    p = param_count(cfg)
    return ((p + chunk - 1) // chunk) * chunk


def flatten_params(cfg: ModelConfig, params) -> jnp.ndarray:
    """Pytree -> flat (K,) vector, zero-padded to a chunk multiple."""
    flat, _ = jax.flatten_util.ravel_pytree(params)
    k = padded_size(cfg)
    return jnp.zeros((k,), jnp.float32).at[: flat.shape[0]].set(flat)


def _unflattener(cfg: ModelConfig):
    _, unravel = jax.flatten_util.ravel_pytree(init_params(cfg))
    p = param_count(cfg)
    return lambda flat: unravel(flat[:p])


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layernorm(x, g):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g


def _attention(cfg: ModelConfig, blk, x):
    b, t, d = x.shape
    qkv = x @ blk["wqkv"]  # (B,T,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) * (cfg.d_head**-0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ blk["wo"]


def forward(cfg: ModelConfig, params, tokens):
    """Causal LM logits for int32 tokens (B, T)."""
    x = params["embed"][tokens] + params["pos"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        blk = params[f"blk{i}"]
        x = x + _attention(cfg, blk, _layernorm(x, blk["ln1"]))
        h = _layernorm(x, blk["ln2"])
        x = x + jax.nn.gelu(h @ blk["w1"]) @ blk["w2"]
    x = _layernorm(x, params["lnf"])
    return x @ params["embed"].T  # tied head


def loss_fn(cfg: ModelConfig, params, tokens):
    """Next-token cross-entropy over tokens (B, T+1)."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# AOT-exported entry points (flat-vector calling convention)
# ---------------------------------------------------------------------------


def make_grad_step(cfg: ModelConfig):
    """grad_step(params_flat (K,), tokens (B, T+1) i32) -> (loss, grads_flat (K,))."""
    unflatten = _unflattener(cfg)
    k = padded_size(cfg)
    p = param_count(cfg)

    def grad_step(params_flat, tokens):
        def flat_loss(pf):
            return loss_fn(cfg, unflatten(pf), tokens)

        loss, g = jax.value_and_grad(flat_loss)(params_flat)
        # Zero the pad region so the PS never folds garbage into the model.
        g = g.at[p:].set(0.0) if p < k else g
        return loss, g

    return grad_step


def make_eval_loss(cfg: ModelConfig):
    """eval_loss(params_flat, tokens) -> (loss,)."""
    unflatten = _unflattener(cfg)

    def eval_loss(params_flat, tokens):
        return (loss_fn(cfg, unflatten(params_flat), tokens),)

    return eval_loss


def make_agg_opt(cfg: ModelConfig, n_workers: int):
    """agg_opt_step over the padded model, using the L1 Pallas kernel."""
    from .kernels.agg_opt import agg_opt

    k = padded_size(cfg)

    def step(grads, params, mom, lr, mu):
        assert grads.shape == (n_workers, k)
        return agg_opt(grads, params, mom, lr, mu)

    return step


def manifest(cfg: ModelConfig, n_workers: int) -> dict[str, Any]:
    """JSON manifest consumed by the Rust coordinator."""
    return {
        "config": dataclasses.asdict(cfg),
        "param_count": param_count(cfg),
        "padded_size": padded_size(cfg),
        "chunk_elems": CHUNK_ELEMS,
        "n_workers": n_workers,
        "keys": key_table(cfg),
    }


def manifest_json(cfg: ModelConfig, n_workers: int) -> str:
    return json.dumps(manifest(cfg, n_workers), indent=1)
