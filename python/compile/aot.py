"""AOT compiler: lower the L2/L1 graphs to HLO text for the Rust runtime.

Run once at build time (`make artifacts`); Python never appears on the
training hot path. Emits into `artifacts/`:

  grad_step.hlo.txt   worker fwd+bwd: (params (K,), tokens (B,T+1) i32)
                        -> (loss f32[], grads f32[K])
  eval_loss.hlo.txt   (params, tokens) -> (loss,)
  agg_opt.hlo.txt     PS hot path via the Pallas kernel:
                        (grads (W,K), params (K,), mom (K,), lr (), mu ())
                        -> (params', mom')
  agg_only.hlo.txt    (grads (W,K)) -> (mean (K,))  [hierarchical reduction]
  quant2bit.hlo.txt   (grad (K,), residual (K,), threshold ())
                        -> (q, new_residual, dequant)
  manifest.json       shapes, key table, chunking constants
  params_init.bin     raw little-endian f32 initial flat parameters

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.agg_opt import agg_only, agg_opt
from .kernels.quant import quant2bit


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text (ids reassigned by the text parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(cfg: M.ModelConfig, n_workers: int, out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    k = M.padded_size(cfg)
    pspec = jax.ShapeDtypeStruct((k,), jnp.float32)
    vspec = jax.ShapeDtypeStruct((k,), jnp.float32)
    gspec = jax.ShapeDtypeStruct((n_workers, k), jnp.float32)
    tokspec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    sspec = jax.ShapeDtypeStruct((), jnp.float32)

    artifacts = {
        "grad_step": jax.jit(M.make_grad_step(cfg)).lower(pspec, tokspec),
        "eval_loss": jax.jit(M.make_eval_loss(cfg)).lower(pspec, tokspec),
        "agg_opt": jax.jit(
            lambda g, p, m, lr, mu: agg_opt(g, p, m, lr, mu)
        ).lower(gspec, pspec, vspec, sspec, sspec),
        "agg_only": jax.jit(agg_only).lower(gspec),
        "quant2bit": jax.jit(
            lambda g, r, t: quant2bit(g, r, t)
        ).lower(pspec, vspec, sspec),
    }
    sizes = {}
    for name, lowered in artifacts.items():
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        sizes[name] = len(text)
        print(f"wrote {path} ({len(text)} chars)")

    # Initial flat parameters, so Rust workers and the pytest oracle start
    # from identical state.
    flat = np.asarray(M.flatten_params(cfg, M.init_params(cfg)), np.float32)
    (out_dir / "params_init.bin").write_bytes(flat.tobytes())
    print(f"wrote {out_dir / 'params_init.bin'} ({flat.nbytes} bytes)")

    man = M.manifest(cfg, n_workers)
    man["artifact_chars"] = sizes
    (out_dir / "manifest.json").write_text(json.dumps(man, indent=1))
    print(f"wrote {out_dir / 'manifest.json'}")
    return man


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-workers", type=int, default=4)
    args = ap.parse_args()
    cfg = M.ModelConfig(
        vocab=args.vocab,
        d_model=args.d_model,
        n_layers=args.n_layers,
        n_heads=args.n_heads,
        d_ff=args.d_ff,
        seq_len=args.seq_len,
        batch=args.batch,
    )
    print(f"model: {M.param_count(cfg)} params, padded {M.padded_size(cfg)}")
    lower_all(cfg, args.n_workers, pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
