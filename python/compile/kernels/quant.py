"""L1 Pallas kernel: 2-bit gradient quantization with error feedback.

The paper (section 5) compares PHub against MXNet's 2-bit compression and
notes PHub composes with gradient compression for further wins. This kernel
implements the MXNet-style threshold quantizer as a chunked elementwise
Pallas kernel so the Rust coordinator can exercise a compressed exchange
path end-to-end.

Elementwise over chunks, same grid discipline as agg_opt: no cross-chunk
state, interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .agg_opt import CHUNK_ELEMS


def _quant_kernel(g_ref, r_ref, t_ref, q_ref, nr_ref, dq_ref):
    acc = g_ref[...] + r_ref[...]
    t = t_ref[0]
    q = jnp.where(acc > t, 1.0, jnp.where(acc < -t, -1.0, 0.0))
    dq = q * t
    q_ref[...] = q
    nr_ref[...] = acc - dq
    dq_ref[...] = dq


def quant2bit(grad, residual, threshold, *, chunk=CHUNK_ELEMS):
    """Quantize a flattened gradient to {-1,0,+1} with error feedback.

    Args:
      grad, residual: (K,) f32, K a multiple of `chunk`.
      threshold: scalar quantization threshold.

    Returns:
      (q, new_residual, dequant): q in {-1,0,+1} f32 (2 bits of information
      per element on the wire), the carried error, and q*threshold.
    """
    (k,) = grad.shape
    if k % chunk != 0:
        raise ValueError(f"size {k} not a multiple of chunk {chunk}")
    t = jnp.asarray(threshold, jnp.float32).reshape(1)
    return pl.pallas_call(
        _quant_kernel,
        grid=(k // chunk,),
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(grad, residual, t)
