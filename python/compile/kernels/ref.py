"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its reference here bit-for-bit (up to float tolerance) under
pytest. They are also used directly by the L2 model tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def agg_opt_ref(grads, params, mom, lr, mu):
    """Reference fused gradient aggregation + Nesterov-momentum SGD.

    This is PHub's "tall aggregation + streaming optimization" hot path
    (paper section 3.2.2) expressed as a single dense update over the whole
    flattened model:

      g     = mean over workers of grads           (aggregation)
      mom'  = mu * mom + g                         (MXNet NAG momentum)
      p'    = p - lr * (g + mu * mom')             (Nesterov lookahead step)

    Args:
      grads: (W, K) per-worker flattened gradients.
      params: (K,) flattened model.
      mom: (K,) momentum buffer.
      lr, mu: scalars (python float or 0-d array).

    Returns:
      (new_params, new_mom), both (K,).
    """
    g = jnp.mean(grads, axis=0)
    new_mom = mu * mom + g
    new_params = params - lr * (g + mu * new_mom)
    return new_params, new_mom


def agg_only_ref(grads):
    """Reference plain aggregation (mean over the worker axis)."""
    return jnp.mean(grads, axis=0)


def quant2bit_ref(grad, residual, threshold):
    """Reference 2-bit gradient quantization with error feedback.

    MXNet-style threshold quantization (paper section 5): accumulate the
    incoming gradient into the residual, emit {-1, 0, +1} per element
    (dequantized value q * threshold), and keep the quantization error as
    the new residual.

    Returns:
      (q, new_residual, dequant) with q in {-1, 0, 1} as float32.
    """
    acc = grad + residual
    q = jnp.where(acc > threshold, 1.0, jnp.where(acc < -threshold, -1.0, 0.0))
    dequant = q * threshold
    new_residual = acc - dequant
    return q, new_residual, dequant
