"""L1 Pallas kernel: fused gradient aggregation + Nesterov SGD.

PHub's core compute hot path (paper section 3.2.2, "tall aggregation"): the
model is split into fixed-size chunks; each chunk is aggregated across all
workers and optimized *independently*, with no cross-chunk synchronization.

Hardware adaptation (DESIGN.md section "Hardware adaptation"): the paper
implements this with AVX loops pinned to cores, keeping the aggregation
buffer resident in L2 cache. On a TPU-shaped machine the same structure is a
1-D Pallas grid over chunks: each grid step stages a (W, CHUNK) gradient tile
plus the (CHUNK,) param/momentum slices into VMEM (the cache analogue),
reduces over the worker axis on the VPU, applies the optimizer in-register,
and performs a single store. The no-coordination property of tall
aggregation *is* the grid: steps share nothing.

All pallas_call sites use interpret=True — the CPU PJRT plugin cannot
execute Mosaic custom-calls; interpret mode lowers to plain HLO so the same
artifact runs under the Rust PJRT CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default chunk size in *elements*. PHub's default wire chunk is 32 KB
# (section 3.2.3) = 8192 f32 elements; we keep the same constant so the
# kernel's unit of parallelism equals the wire unit of transfer.
CHUNK_ELEMS = 8192


def _agg_opt_kernel(g_ref, p_ref, m_ref, lr_ref, mu_ref, po_ref, mo_ref, *, n_workers):
    """One grid step = one PHub chunk: aggregate over workers, then NAG."""
    # (W, C) tile -> (C,) mean. The worker axis is small (a rack), the chunk
    # axis is the vector axis — this is the "tall" layout.
    g = jnp.sum(g_ref[...], axis=0) * (1.0 / n_workers)
    lr = lr_ref[0]
    mu = mu_ref[0]
    new_m = mu * m_ref[...] + g
    po_ref[...] = p_ref[...] - lr * (g + mu * new_m)
    mo_ref[...] = new_m


def agg_opt(grads, params, mom, lr, mu, *, chunk=CHUNK_ELEMS):
    """Fused aggregate + Nesterov-SGD over a flattened model.

    Args:
      grads: (W, K) per-worker gradients; K must be a multiple of `chunk`
        (the AOT path pads the model to a chunk multiple).
      params, mom: (K,) model and momentum.
      lr, mu: scalar learning rate and momentum coefficient (traced).
      chunk: elements per chunk (grid step).

    Returns:
      (new_params, new_mom).
    """
    n_workers, k = grads.shape
    if k % chunk != 0:
        raise ValueError(f"model size {k} not a multiple of chunk {chunk}")
    grid = (k // chunk,)
    lr = jnp.asarray(lr, jnp.float32).reshape(1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1)
    kernel = functools.partial(_agg_opt_kernel, n_workers=n_workers)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_workers, chunk), lambda i: (0, i)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), params.dtype),
            jax.ShapeDtypeStruct((k,), mom.dtype),
        ],
        interpret=True,
    )(grads, params, mom, lr, mu)


def _agg_kernel(g_ref, o_ref, *, n_workers):
    o_ref[...] = jnp.sum(g_ref[...], axis=0) * (1.0 / n_workers)


def agg_only(grads, *, chunk=CHUNK_ELEMS):
    """Plain chunked mean-aggregation over the worker axis (no optimizer).

    Used by the hierarchical-reduction path, where per-rack PBoxes aggregate
    locally, cross-rack reduction combines rack sums, and only then does the
    optimizer run (paper section 3.4).
    """
    n_workers, k = grads.shape
    if k % chunk != 0:
        raise ValueError(f"model size {k} not a multiple of chunk {chunk}")
    kernel = functools.partial(_agg_kernel, n_workers=n_workers)
    return pl.pallas_call(
        kernel,
        grid=(k // chunk,),
        in_specs=[pl.BlockSpec((n_workers, chunk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((chunk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), grads.dtype),
        interpret=True,
    )(grads)
