#!/usr/bin/env python3
"""Diff fresh bench JSON summaries against the committed baseline.

Usage: bench_diff.py BENCH_baseline.json BENCH_<name>.json...

Each bench binary emits a single-line JSON object (its last stdout line)
with a "bench" name key; ``BENCH_baseline.json`` maps bench name -> that
object as committed. The *schema* is the contract: a key missing from or
added to a fresh summary fails the run (someone changed a bench without
updating the baseline, silently breaking the perf trajectory), and string
fields must match exactly. Numeric values only *warn* when they drift
more than DRIFT_X from the baseline — shared CI runners are not a stable
perf environment, so numbers inform rather than gate.
"""

import json
import sys

DRIFT_X = 3.0

def fail(msg):
    print(f"bench_diff: FAIL: {msg}", file=sys.stderr)
    return 1

def main(argv):
    if len(argv) < 3:
        return fail("usage: bench_diff.py <baseline.json> <fresh.json>...")
    with open(argv[1]) as f:
        baseline = json.load(f)
    rc = 0
    for path in argv[2:]:
        with open(path) as f:
            line = f.read().strip()
        try:
            fresh = json.loads(line)
        except json.JSONDecodeError as e:
            rc |= fail(f"{path} is not a JSON object ({e}); did the bench panic?")
            continue
        name = fresh.get("bench")
        if name not in baseline:
            rc |= fail(f"{path}: bench {name!r} has no baseline entry")
            continue
        # Underscore keys are baseline-side commentary, not schema.
        base = {k: v for k, v in baseline[name].items() if not k.startswith("_")}
        missing = sorted(set(base) - set(fresh))
        extra = sorted(set(fresh) - set(base))
        if missing or extra:
            rc |= fail(
                f"{path}: schema drift vs baseline[{name!r}] "
                f"(missing: {missing}, extra: {extra}); "
                f"update BENCH_baseline.json with the bench"
            )
            continue
        for key, want in base.items():
            got = fresh[key]
            if isinstance(want, str):
                if got != want:
                    rc |= fail(f"{path}: {key} = {got!r}, baseline {want!r}")
            elif isinstance(want, (int, float)) and want != 0:
                ratio = got / want
                if not (1.0 / DRIFT_X <= ratio <= DRIFT_X):
                    print(
                        f"bench_diff: warn: {name}.{key} = {got} is {ratio:.2f}x "
                        f"baseline ({want})"
                    )
        print(f"bench_diff: {path}: schema OK vs baseline[{name!r}]")
    return rc

if __name__ == "__main__":
    sys.exit(main(sys.argv))
