#!/usr/bin/env python3
"""Tests for tools/bench_diff.py — the CI schema gate for bench JSON.

bench_diff is the only thing standing between "someone reshaped a bench's
JSON summary" and "the perf trajectory silently stops being comparable",
so its contract is pinned here: schema drift (missing/extra keys, string
mismatch, malformed JSON) fails the run; numeric drift beyond DRIFT_X
only warns; ``_``-prefixed baseline keys are commentary, not schema.

Runs under pytest (``pytest tools/test_bench_diff.py``) or standalone
(``python3 tools/test_bench_diff.py``). Each case drives the real script
through a subprocess, exactly as CI invokes it.
"""

import json
import os
import subprocess
import sys
import tempfile

BENCH_DIFF = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_diff.py")


def run_diff(baseline, fresh_objects):
    """Invoke bench_diff.py on a baseline dict and per-bench fresh JSON
    strings; returns (returncode, stdout, stderr)."""
    with tempfile.TemporaryDirectory() as td:
        base_path = os.path.join(td, "BENCH_baseline.json")
        with open(base_path, "w") as f:
            json.dump(baseline, f)
        fresh_paths = []
        for i, obj in enumerate(fresh_objects):
            p = os.path.join(td, f"BENCH_fresh{i}.json")
            with open(p, "w") as f:
                f.write(obj if isinstance(obj, str) else json.dumps(obj))
            fresh_paths.append(p)
        proc = subprocess.run(
            [sys.executable, BENCH_DIFF, base_path] + fresh_paths,
            capture_output=True,
            text=True,
        )
        return proc.returncode, proc.stdout, proc.stderr


BASELINE = {
    "ring": {
        "_comment": "underscore keys are commentary, never schema",
        "bench": "ring",
        "cap": 1024,
        "ring_mops": 40.0,
    }
}


def test_matching_schema_passes():
    rc, out, err = run_diff(BASELINE, [{"bench": "ring", "cap": 1024, "ring_mops": 41.5}])
    assert rc == 0, err
    assert "schema OK" in out


def test_missing_key_fails_as_schema_drift():
    rc, _, err = run_diff(BASELINE, [{"bench": "ring", "cap": 1024}])
    assert rc == 1
    assert "schema drift" in err and "ring_mops" in err


def test_new_key_fails_until_baseline_updated():
    fresh = {"bench": "ring", "cap": 1024, "ring_mops": 40.0, "new_mops": 1.0}
    rc, _, err = run_diff(BASELINE, [fresh])
    assert rc == 1
    assert "schema drift" in err and "new_mops" in err
    # Adding the key to the baseline is exactly the documented fix.
    widened = {"ring": dict(BASELINE["ring"], new_mops=1.0)}
    rc, out, _ = run_diff(widened, [fresh])
    assert rc == 0
    assert "schema OK" in out


def test_numeric_drift_warns_but_passes():
    rc, out, _ = run_diff(BASELINE, [{"bench": "ring", "cap": 1024, "ring_mops": 400.0}])
    assert rc == 0
    assert "warn" in out and "10.00x" in out


def test_zero_baseline_skips_ratio():
    base = {"ring": {"bench": "ring", "zero_gbps": 0}}
    rc, out, _ = run_diff(base, [{"bench": "ring", "zero_gbps": 12.0}])
    assert rc == 0, "a 0 baseline (e.g. a tier the runner lacks) must not divide"
    assert "warn" not in out


def test_string_mismatch_fails():
    base = {"ring": {"bench": "ring", "mode": "pooled"}}
    rc, _, err = run_diff(base, [{"bench": "ring", "mode": "vec"}])
    assert rc == 1
    assert "'vec'" in err and "'pooled'" in err


def test_unknown_bench_name_fails():
    rc, _, err = run_diff(BASELINE, [{"bench": "nonesuch", "cap": 1}])
    assert rc == 1
    assert "no baseline entry" in err


def test_malformed_json_fails_with_panic_hint():
    rc, _, err = run_diff(BASELINE, ['thread panicked at "oops"'])
    assert rc == 1
    assert "did the bench panic?" in err


def test_one_bad_file_fails_run_but_good_files_still_checked():
    good = {"bench": "ring", "cap": 1024, "ring_mops": 40.0}
    rc, out, err = run_diff(BASELINE, [{"bench": "ring", "cap": 1024}, good])
    assert rc == 1
    assert "schema drift" in err
    assert "fresh1" in out and "schema OK" in out


def main():
    tests = [(n, f) for n, f in sorted(globals().items()) if n.startswith("test_")]
    for name, fn in tests:
        fn()
        print(f"{name} OK")
    print(f"test_bench_diff: {len(tests)} passed")


if __name__ == "__main__":
    main()
