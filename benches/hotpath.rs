//! Live hot-path microbenchmarks (run via `cargo bench --bench hotpath`).
//!
//! Measures the real Rust implementation (not the simulator):
//! * tall vs wide aggregation throughput (section 4.5: tall ~20x);
//! * the aggregation inner loop's memory bandwidth vs a DRAM roofline;
//! * live server push_pull round latency vs core count;
//! * end-to-end exchange throughput scaling with worker threads.
//!
//! Results feed EXPERIMENTS.md section Perf.

use std::sync::Arc;
use std::time::Instant;

use phub::baseline::wide;
use phub::coordinator::aggregation::{add_assign, ChunkAggregator};
use phub::coordinator::optimizer::{NesterovSgd, Optimizer};
use phub::coordinator::server::{PHubServer, ServerConfig};
use phub::coordinator::KeyTable;
use phub::prop::Rng;

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {label:<46} {:>10.3} ms/op", dt * 1e3);
    dt
}

/// Raw aggregation inner loop: GB/s of gradient input processed.
fn agg_inner_loop() {
    println!("== aggregation inner loop (single core) ==");
    let mut rng = Rng::new(1);
    let n = 1 << 22; // 16 MB of f32
    let src = rng.vec_f32(n, 1.0);
    let mut acc = rng.vec_f32(n, 1.0);
    let dt = bench("add_assign 16MB", 20, || {
        add_assign(&mut acc, &src);
    });
    let gbps = (n * 4) as f64 / dt / 1e9;
    println!("  -> {gbps:.1} GB/s input ({:.1} GB/s load+store traffic)", gbps * 3.0);
}

/// Tall vs wide: aggregate 8 worker gradients of one 64MB key.
fn tall_vs_wide() {
    println!("\n== tall vs wide aggregation+optimization (8 workers, 64 MB key) ==");
    let mut rng = Rng::new(2);
    let len = 16 << 20; // 16M f32 = 64MB
    let workers = 8;
    let grads: Vec<Vec<f32>> = (0..workers).map(|_| rng.vec_f32(len, 1.0)).collect();
    let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let opt = NesterovSgd {
        lr: 0.01,
        momentum: 0.9,
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // Tall: chunk-per-core, no synchronization. Emulate P parallel cores,
    // each owning len/P contiguous chunks, via scoped threads.
    let chunk = 8192usize;
    let mut params_t = rng.vec_f32(len, 1.0);
    let mut state_t = vec![0.0f32; len];
    let dt_tall = bench(&format!("tall ({} cores, 32KB chunks)", threads), 3, || {
        let per = len / threads;
        std::thread::scope(|s| {
            let mut p_rest: &mut [f32] = &mut params_t;
            let mut s_rest: &mut [f32] = &mut state_t;
            for t in 0..threads {
                let (p_mine, p_next) = p_rest.split_at_mut(per.min(p_rest.len()));
                let (s_mine, s_next) = s_rest.split_at_mut(per.min(s_rest.len()));
                p_rest = p_next;
                s_rest = s_next;
                let grads = &grads;
                s.spawn(move || {
                    let base = t * per;
                    let mut agg = ChunkAggregator::new(chunk, workers);
                    let opt = NesterovSgd {
                        lr: 0.01,
                        momentum: 0.9,
                    };
                    for (ci, (pc, sc)) in p_mine
                        .chunks_mut(chunk)
                        .zip(s_mine.chunks_mut(chunk))
                        .enumerate()
                    {
                        let off = base + ci * chunk;
                        if pc.len() != chunk {
                            break;
                        }
                        for w in 0..workers {
                            agg.absorb(w, &grads[w][off..off + chunk]).unwrap();
                        }
                        let mean = agg.take_mean().unwrap();
                        opt.step(pc, sc, mean);
                    }
                });
            }
        });
    });

    // Wide: gang threads over the whole key, two barrier passes.
    let mut params_w = rng.vec_f32(len, 1.0);
    let mut state_w = vec![0.0f32; len];
    let dt_wide = bench(&format!("wide ({} threads, whole key)", threads), 3, || {
        wide::wide_exchange(&opt, &grad_refs, &mut params_w, &mut state_w, threads);
    });
    println!(
        "  -> tall/wide speedup: {:.1}x (paper: ~20x incl. overlap effects)",
        dt_wide / dt_tall
    );
}

/// Live server round latency vs core count.
fn server_scaling() {
    println!("\n== live PHubServer push_pull round (4 workers, 32 MB model) ==");
    let elems = 8 << 20;
    let workers = 4;
    for cores in [1usize, 2, 4, 8] {
        let server = PHubServer::start(ServerConfig::cores(cores));
        let job = server.init_job(
            KeyTable::flat(elems, 8192),
            &vec![0.0f32; elems],
            Arc::new(NesterovSgd {
                lr: 0.01,
                momentum: 0.9,
            }),
            workers,
        );
        let mut handles: Vec<_> = (0..workers).map(|w| server.worker(job, w)).collect();
        let grad = vec![0.5f32; elems];
        let label = format!("{cores} cores");
        bench(&label, 5, || {
            std::thread::scope(|s| {
                for h in handles.iter_mut() {
                    let g = &grad;
                    s.spawn(move || {
                        let _ = h.push_pull(g);
                    });
                }
            });
        });
        PHubServer::shutdown(server);
    }
}

/// Exchange throughput scaling with worker count (fixed 4 cores).
fn worker_scaling() {
    println!("\n== live exchange throughput vs workers (16 MB model, 4 cores) ==");
    let elems = 4 << 20;
    for workers in [1usize, 2, 4, 8] {
        let server = PHubServer::start(ServerConfig::cores(4));
        let job = server.init_job(
            KeyTable::flat(elems, 8192),
            &vec![0.0f32; elems],
            Arc::new(NesterovSgd {
                lr: 0.01,
                momentum: 0.9,
            }),
            workers,
        );
        let mut handles: Vec<_> = (0..workers).map(|w| server.worker(job, w)).collect();
        let grad = vec![0.5f32; elems];
        let rounds = 8;
        let t0 = Instant::now();
        for _ in 0..rounds {
            std::thread::scope(|s| {
                for h in handles.iter_mut() {
                    let g = &grad;
                    s.spawn(move || {
                        let _ = h.push_pull(g);
                    });
                }
            });
        }
        let dt = t0.elapsed().as_secs_f64();
        let gbps = (rounds * workers * elems * 4 * 2) as f64 / dt / 1e9;
        println!(
            "  {workers} workers: {:>7.2} rounds/s, {gbps:>6.2} GB/s through the server",
            rounds as f64 / dt
        );
        PHubServer::shutdown(server);
    }
}

fn main() {
    let t0 = Instant::now();
    agg_inner_loop();
    tall_vs_wide();
    server_scaling();
    worker_scaling();
    println!("\n[hotpath done in {:.1}s]", t0.elapsed().as_secs_f64());
}
