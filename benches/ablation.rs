//! Ablation study: remove PHub's design choices one at a time and measure
//! the cost (the DESIGN.md §Perf ablations; complements section 4.3.2's
//! "importance of each optimization" goal).
//!
//! Run: `cargo bench --bench ablation`

use phub::compute::Gpu;
use phub::config::{ClusterConfig, NetConfig, PsConfig};
use phub::dnn::Dnn;
use phub::sim;

struct Ablation {
    name: &'static str,
    apply: fn(ClusterConfig) -> ClusterConfig,
}

fn ablations() -> Vec<Ablation> {
    vec![
        Ablation {
            name: "full PHub/PBox",
            apply: |c| c,
        },
        Ablation {
            name: "- fine chunking (4MB chunks)",
            apply: |mut c| {
                c.exchange.chunk_bytes = 4 * 1024 * 1024;
                c
            },
        },
        Ablation {
            name: "- tall aggregation (wide gang)",
            apply: |mut c| {
                c.exchange.tall_aggregation = false;
                c
            },
        },
        Ablation {
            name: "- cached agg/opt (non-temporal)",
            apply: |mut c| {
                c.exchange.cached_agg = false;
                c
            },
        },
        Ablation {
            name: "- key-by-interface (worker-by-iface)",
            apply: |mut c| {
                c.exchange.key_by_interface = false;
                c
            },
        },
        Ablation {
            name: "- multi-NIC balance (1 NIC host)",
            apply: |mut c| {
                c.ps_host.nics = 1;
                c
            },
        },
        Ablation {
            name: "- non-colocation (PShard/CS)",
            apply: |c| c.with_ps(PsConfig::ColocatedSharded),
        },
    ]
}

fn main() {
    let t0 = std::time::Instant::now();
    println!("== Ablation: PHub design choices, 8 workers, 10 Gbps ==");
    for (abbrev, gpu) in [
        ("AN", Gpu::Gtx1080Ti),
        ("RN50", Gpu::Gtx1080Ti),
        ("RN18", Gpu::ZeroCompute),
    ] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let label = if matches!(gpu, Gpu::ZeroCompute) {
            format!("{abbrev} (ZeroCompute)")
        } else {
            abbrev.to_string()
        };
        println!("\n  {label}:");
        let mut base = 0.0;
        for ab in ablations() {
            let c = (ab.apply)(ClusterConfig::paper_testbed().with_net(NetConfig::cloud_10g()));
            let r = sim::simulate(&c, &d, gpu);
            if ab.name.starts_with("full") {
                base = r.throughput;
            }
            println!(
                "    {:<38} {:>9.1} samples/s  ({:>5.1}% of full)",
                ab.name,
                r.throughput,
                100.0 * r.throughput / base
            );
        }
    }
    println!("\n[ablation done in {:.1}s]", t0.elapsed().as_secs_f64());
}
