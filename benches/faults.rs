//! Recovery-overhead bench (run via `cargo bench --bench faults`).
//!
//! Measures end-to-end TCP training throughput with every worker
//! connection tunnelled through a `coordinator::faults::FaultProxy`, at
//! increasing per-frame fault rates. Rate 0 is the control (the proxy
//! forwards verbatim, so the comparison isolates fault *recovery* cost,
//! not proxy cost): the deltas price the epoch-bump/rollback/replay
//! recovery path plus reconnect latency under injected kills, cuts,
//! delays, and duplicates.
//!
//! Results feed EXPERIMENTS.md section Perf; the last stdout line is the
//! JSON summary for BENCH_faults.json.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use phub::coordinator::faults::{FaultPlan, FaultProxy, FaultRates};
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};

const MODEL_ELEMS: u64 = 1024;
const CHUNK_ELEMS: u64 = 256;
const N_CHUNKS: u64 = MODEL_ELEMS / CHUNK_ELEMS;
const WORKERS: u32 = 2;
const ROUNDS: usize = 40;

fn spec() -> JobSpec {
    JobSpec {
        model_elems: MODEL_ELEMS,
        chunk_elems: CHUNK_ELEMS,
        n_workers: WORKERS,
        lr: 0.01,
        momentum: 0.9,
    }
}

/// Drive one seat to `ROUNDS` completed rounds, reconnecting through a
/// fresh proxy on every injected death (the production recovery path).
fn drive_seat(leader: SocketAddr, rate: f32, seed: u64) {
    let s = spec();
    let n = s.model_elems as usize;
    let rates = FaultRates::uniform(rate);
    let mut model = vec![0.0f32; n];
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut attempt = 0u64;
    loop {
        assert!(Instant::now() < deadline, "faults bench wedged at rate {rate}");
        attempt += 1;
        let plan = FaultPlan::new(seed ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15), rates);
        let Ok(proxy) = FaultProxy::spawn(leader, plan) else {
            continue;
        };
        let mut w = match TcpWorker::connect(proxy.addr(), 1, s) {
            Ok(w) => w,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
        };
        let mut r = w.rounds_done() as usize;
        let slot = w.slot as usize;
        let mut died = false;
        while r < ROUNDS {
            let g: Vec<f32> = (0..n)
                .map(|i| (slot as f32 - 0.5) * 0.3 + (r as f32 + 1.0) * 0.01 + i as f32 * 1e-4)
                .collect();
            match w.push_pull_into(&g, &mut model) {
                Ok(()) => r += 1,
                Err(_) => {
                    died = true;
                    break;
                }
            }
        }
        if !died {
            w.bye();
            return;
        }
    }
}

/// Rounds/s for one full 2-worker run at the given per-frame fault rate.
fn run_at(rate: f32, seed: u64) -> f64 {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = leader.local_addr();
    let t0 = Instant::now();
    let joins: Vec<_> = (0..WORKERS as u64)
        .map(|i| {
            let sub = seed ^ (i + 1).wrapping_mul(0xA24B_AED4_963E_E407);
            std::thread::spawn(move || drive_seat(addr, rate, sub))
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    ROUNDS as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!(
        "== faults bench: {N_CHUNKS} x {CHUNK_ELEMS}-elem chunks, {WORKERS} workers, \
         {ROUNDS} rounds, proxied ==",
    );
    let _ = run_at(0.0, 11); // warm-up
    let f0 = run_at(0.0, 11);
    let f1 = run_at(0.01, 12);
    let f5 = run_at(0.05, 13);
    println!("  fault rate 0%  (control):  {f0:>9.1} rounds/s");
    println!("  fault rate 1%:             {f1:>9.1} rounds/s ({:.2}x control)", f0 / f1);
    println!("  fault rate 5%:             {f5:>9.1} rounds/s ({:.2}x control)", f0 / f5);
    println!("faults bench OK");
    // Single-line JSON summary for BENCH_faults.json (keep last on
    // stdout).
    println!(
        "{{\"bench\":\"faults\",\"model_elems\":{MODEL_ELEMS},\"chunks\":{N_CHUNKS},\
         \"workers\":{WORKERS},\"rounds\":{ROUNDS},\
         \"rps_f0\":{f0:.1},\"rps_f1\":{f1:.1},\"rps_f5\":{f5:.1}}}"
    );
}
