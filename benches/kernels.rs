//! SIMD kernel-tier and placement microbench (run via `cargo bench
//! --bench kernels`).
//!
//! Part one sweeps every kernel tier available on this host
//! (scalar / SSE2 / AVX2) over the five data-plane hot loops of
//! `coordinator::kernels` — LE-byte copy, LE-byte absorb fold, fused
//! 2-bit dequant+absorb, fused mean+SGD, fused mean+Nesterov — and
//! reports GB/s per (tier, kernel). The byte basis is the dense f32
//! footprint (`elems * 4`) for every kernel, including the quantized
//! fold whose *wire* traffic is 16x smaller: the number answers "how
//! fast does this loop sweep the accumulator", which is the
//! memory-bandwidth story of paper §4.3, and keeps tiers and kernels
//! directly comparable.
//!
//! Part two runs the same in-process multi-core server round loop under
//! both chunk→core placement modes (PHub key-affinity vs LPT
//! interleave) and reports rounds/s for each. Placement changes
//! locality only, never results (`server.rs` tests assert
//! bit-identical training), so any gap here is pure cache behavior.
//!
//! Emits a single-line JSON summary (last stdout line) suitable for
//! `BENCH_kernels.json` trajectory tracking. Tiers this host cannot run
//! are reported as 0.0 rather than omitted so the JSON schema is
//! identical on every machine (`tools/bench_diff.py` hard-fails on key
//! drift but only warns on numeric drift).

use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use phub::coordinator::kernels::{self, KernelTier};
use phub::coordinator::mapping::PlacementMode;
use phub::coordinator::optimizer::NesterovSgd;
use phub::coordinator::server::{PHubServer, ServerConfig};
use phub::coordinator::KeyTable;
use phub::prop::Rng;

/// Elements per kernel invocation: 32 Ki f32 = 128 KiB, roughly the
/// paper's chunk scale — large enough to amortize dispatch, small
/// enough to stay cache-resident so the tiers differentiate on compute.
const ELEMS: usize = 32 * 1024;
const REPS: usize = 2000;
const WARM_REPS: usize = 50;

// Placement comparison: a model big enough that per-core extents span
// many chunks (64 x 4096 f32 = 1 MiB model over 4 cores).
const PLACE_CHUNKS: usize = 64;
const PLACE_CHUNK_ELEMS: usize = 4096;
const PLACE_CORES: usize = 4;
const PLACE_WORKERS: usize = 2;
const PLACE_WARM_ROUNDS: usize = 4;
const PLACE_ROUNDS: usize = 40;

const KERNELS: [&str; 5] = ["copy", "absorb", "dequant", "sgd", "nesterov"];
/// Every tier the schema reports, available here or not.
const TIERS: [KernelTier; 3] = [KernelTier::Scalar, KernelTier::Sse2, KernelTier::Avx2];

/// Time `f` over the standard rep count and convert to GB/s on the
/// dense-f32 byte basis.
fn gbps<F: FnMut()>(mut f: F) -> f64 {
    for _ in 0..WARM_REPS {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..REPS {
        f();
    }
    let dt = t0.elapsed().as_secs_f64();
    (ELEMS * 4 * REPS) as f64 / dt / 1e9
}

/// GB/s for the five kernels on one tier, in [`KERNELS`] order.
fn bench_tier(tier: KernelTier, rng: &mut Rng) -> [f64; 5] {
    let src = rng.vec_f32(ELEMS, 1.0);
    let mut le_bytes = Vec::with_capacity(ELEMS * 4);
    for v in &src {
        le_bytes.extend_from_slice(&v.to_le_bytes());
    }
    // 2-bit packed codes covering all four levels, incl. reserved 0b11.
    let packed: Vec<u8> = (0..ELEMS.div_ceil(4))
        .map(|_| (rng.next_u64() & 0xff) as u8)
        .collect();
    let mut dst = vec![0.0f32; ELEMS];
    let mut acc = rng.vec_f32(ELEMS, 1.0);
    let mut params = rng.vec_f32(ELEMS, 1.0);
    let mut state = vec![0.0f32; ELEMS];

    let copy = gbps(|| {
        kernels::copy_f32s_le_tier(tier, &mut dst, &le_bytes);
        black_box(&dst);
    });
    let absorb = gbps(|| {
        kernels::add_assign_le_tier(tier, &mut acc, &le_bytes);
        black_box(&acc);
    });
    let dequant = gbps(|| {
        kernels::add_assign_dequant_tier(tier, &mut acc, 0.01, &packed);
        black_box(&acc);
    });
    let sgd = gbps(|| {
        kernels::sgd_step_scaled_tier(tier, &mut params, &src, 0.25, 0.01);
        black_box(&params);
    });
    let nesterov = gbps(|| {
        kernels::nesterov_step_scaled_tier(tier, &mut params, &mut state, &src, 0.25, 0.01, 0.9);
        black_box(&params);
    });
    [copy, absorb, dequant, sgd, nesterov]
}

/// Rounds/s of the full in-process server loop under one placement
/// mode: `PLACE_WORKERS` synchronous workers push-pulling the whole
/// model each round over `PLACE_CORES` aggregation cores.
fn bench_placement(mode: PlacementMode) -> f64 {
    let n = PLACE_CHUNKS * PLACE_CHUNK_ELEMS;
    let server = PHubServer::start(ServerConfig {
        placement: mode,
        ..ServerConfig::cores(PLACE_CORES)
    });
    let init = vec![0.1f32; n];
    let job = server.init_job(
        KeyTable::flat(n, PLACE_CHUNK_ELEMS),
        &init,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        PLACE_WORKERS,
    );
    let mut handles: Vec<_> = (0..PLACE_WORKERS).map(|w| server.worker(job, w)).collect();
    let mut rng = Rng::new(23);
    let grad = rng.vec_f32(n, 1.0);
    let run_rounds = |handles: &mut Vec<_>, rounds: usize| {
        std::thread::scope(|s| {
            for h in handles.iter_mut() {
                let grad = &grad;
                s.spawn(move || {
                    for _ in 0..rounds {
                        black_box(h.push_pull(grad));
                    }
                });
            }
        });
    };
    run_rounds(&mut handles, PLACE_WARM_ROUNDS);
    let t0 = Instant::now();
    run_rounds(&mut handles, PLACE_ROUNDS);
    let dt = t0.elapsed().as_secs_f64();
    drop(handles);
    PHubServer::shutdown(server);
    PLACE_ROUNDS as f64 / dt
}

fn main() {
    let active = kernels::active_tier();
    println!(
        "== kernels: {ELEMS} f32/call x {REPS} reps; active tier {} ==",
        active.name()
    );

    let mut rng = Rng::new(17);
    // (tier, per-kernel GB/s); unavailable tiers stay all-zero.
    let mut results = [[0.0f64; 5]; 3];
    for (ti, &tier) in TIERS.iter().enumerate() {
        if !kernels::tier_available(tier) {
            println!("  {:<8} unavailable on this host", tier.name());
            continue;
        }
        results[ti] = bench_tier(tier, &mut rng);
        let r = &results[ti];
        println!(
            "  {:<8} copy {:>6.2}  absorb {:>6.2}  dequant {:>6.2}  \
             sgd {:>6.2}  nesterov {:>6.2}  GB/s",
            tier.name(),
            r[0],
            r[1],
            r[2],
            r[3],
            r[4]
        );
    }

    println!(
        "== placement: {PLACE_CHUNKS} x {PLACE_CHUNK_ELEMS}-elem chunks, \
         {PLACE_CORES} cores, {PLACE_WORKERS} workers, {PLACE_ROUNDS} rounds =="
    );
    let interleave_rps = bench_placement(PlacementMode::Interleave);
    let affine_rps = bench_placement(PlacementMode::Affine);
    println!("  interleave {interleave_rps:>8.1} rounds/s");
    println!(
        "  affine     {affine_rps:>8.1} rounds/s  ({:+.1}%)",
        (affine_rps / interleave_rps - 1.0) * 100.0
    );
    println!("kernels OK");

    // Single-line JSON summary for BENCH_kernels.json trajectory
    // tracking (keep last on stdout). All tier keys always present;
    // active_tier_idx is numeric so a host without AVX2 drifts instead
    // of hard-failing the schema gate.
    let mut json = format!(
        "{{\"bench\":\"kernels\",\"elems\":{ELEMS},\"reps\":{REPS},\
         \"chunks\":{PLACE_CHUNKS},\"chunk_elems\":{PLACE_CHUNK_ELEMS},\
         \"rounds\":{PLACE_ROUNDS},\"active_tier_idx\":{}",
        active as u8
    );
    for (ti, &tier) in TIERS.iter().enumerate() {
        for (ki, kernel) in KERNELS.iter().enumerate() {
            json.push_str(&format!(
                ",\"{}_{}_gbps\":{:.3}",
                tier.name(),
                kernel,
                results[ti][ki]
            ));
        }
    }
    json.push_str(&format!(
        ",\"interleave_rps\":{interleave_rps:.3},\"affine_rps\":{affine_rps:.3}}}"
    ));
    println!("{json}");
}
