//! Flat vs two-level (leader-of-leaders) rounds/s (run via `cargo bench
//! --bench hierarchy`).
//!
//! Drives the real in-process fabric both ways: a flat deployment puts
//! all leaf workers on one leader; a two-level deployment puts `k`
//! workers on each of `r` RackRelay servers whose uplink sums pump into
//! one root (paper section 3.4, Figure 19). In-process there is no
//! oversubscribed cross-rack core, so two-level measures pure *overhead*
//! of the extra level — the paper's benefit model
//! (`hierarchy::hierarchical_beneficial`) only favors it when the
//! cross-rack bottleneck is thin, which shared memory is not. The bench
//! therefore reports the overhead honestly and checks the cost model
//! agrees that a fat-core deployment should not go hierarchical.
//!
//! Emits a single-line JSON summary (last stdout line) suitable for
//! `BENCH_hierarchy.json` trajectory tracking.
//!
//! Results feed EXPERIMENTS.md section Perf.

use std::sync::Arc;
use std::time::Instant;

use phub::coordinator::chunk::KeyTable;
use phub::coordinator::engine::Reply;
use phub::coordinator::hierarchy::{hierarchical_beneficial, HierBandwidths};
use phub::coordinator::optimizer::NesterovSgd;
use phub::coordinator::pool::{BytePool, Pool};
use phub::coordinator::server::{PHubServer, ServerConfig};

const WORKERS_PER_RACK: usize = 2;
const CHUNKS: usize = 16;
const CHUNK_ELEMS: usize = 8192;
const ELEMS: usize = CHUNKS * CHUNK_ELEMS;
const ROUNDS: usize = 30;

fn opt() -> Arc<NesterovSgd> {
    Arc::new(NesterovSgd {
        lr: 0.01,
        momentum: 0.9,
    })
}

fn grad_for(seat: usize) -> Vec<f32> {
    (0..ELEMS)
        .map(|i| ((i + 13 * seat) % 11) as f32 * 0.01)
        .collect()
}

/// All `racks * k` leaves on one flat leader; returns rounds/s.
fn bench_flat(racks: usize, k: usize) -> f64 {
    let leaves = racks * k;
    let server = PHubServer::start(ServerConfig::cores(4));
    let init = vec![0.1f32; ELEMS];
    let job = server.init_job(KeyTable::flat(ELEMS, CHUNK_ELEMS), &init, opt(), leaves);
    let mut handles: Vec<_> = (0..leaves).map(|w| server.worker(job, w)).collect();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (w, h) in handles.iter_mut().enumerate() {
            let g = grad_for(w);
            s.spawn(move || {
                for _ in 0..ROUNDS {
                    h.push_pull(&g);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    PHubServer::shutdown(server);
    ROUNDS as f64 / dt
}

/// `racks` RackRelay servers of `k` workers each, raw sums pumped into
/// one root with per-rack weight `k`; returns rounds/s.
fn bench_two_level(racks: usize, k: usize) -> f64 {
    let table = || KeyTable::flat(ELEMS, CHUNK_ELEMS);
    let init = vec![0.1f32; ELEMS];
    let root = PHubServer::start(ServerConfig::cores(2));
    let jr = root.init_job(table(), &init, opt(), racks);
    for ri in 0..racks {
        root.set_worker_weight(jr, ri as u32, k as u32);
    }
    let pool: Arc<BytePool> = Pool::new(CHUNKS);
    let mut rack_srvs = Vec::new();
    let mut pumps = Vec::new();
    let mut leaf_handles = Vec::new();
    for ri in 0..racks {
        let srv = PHubServer::start(ServerConfig::cores(2));
        let (job, mut up) = srv.init_relay_job(table(), &init, opt(), k);
        for w in 0..k {
            leaf_handles.push((ri * k + w, srv.worker(job, w)));
        }
        let mut root_h = root.worker(jr, ri);
        let pool = pool.clone();
        pumps.push(std::thread::spawn(move || {
            for _ in 0..ROUNDS {
                for _ in 0..CHUNKS {
                    match up.recv_sum() {
                        Some(Reply::Sum { chunk, data, .. }) => {
                            root_h.push_chunk(chunk, data[..].into(), true);
                        }
                        other => panic!("pump expected Sum, got {other:?}"),
                    }
                }
                for _ in 0..CHUNKS {
                    match root_h.recv_reply() {
                        Reply::Chunk { chunk, data, .. } => {
                            let mut fb = pool.take();
                            for x in &data[..] {
                                fb.extend_from_slice(&x.to_le_bytes());
                            }
                            up.install_chunk_bytes(chunk, fb, 0);
                        }
                        other => panic!("pump expected Chunk, got {other:?}"),
                    }
                }
                root_h.advance_round();
            }
        }));
        rack_srvs.push(srv);
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (seat, h) in leaf_handles.iter_mut() {
            let g = grad_for(*seat);
            s.spawn(move || {
                for _ in 0..ROUNDS {
                    h.push_pull(&g);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    for p in pumps {
        p.join().unwrap();
    }
    for srv in rack_srvs {
        PHubServer::shutdown(srv);
    }
    PHubServer::shutdown(root);
    ROUNDS as f64 / dt
}

fn main() {
    println!(
        "== hierarchy: {CHUNKS} x {CHUNK_ELEMS}-elem chunks ({} KB model), \
         {WORKERS_PER_RACK} workers/rack, {ROUNDS} rounds ==",
        ELEMS * 4 >> 10
    );
    // Shared memory is a fat core: the paper's benefit model must say
    // "don't go hierarchical here" (it pays only behind a thin
    // cross-rack bottleneck), so the measured two-level numbers below
    // are the overhead of the extra level, not a contradiction.
    let fat_core = HierBandwidths {
        b_pbox: 12.5e9,
        b_core: 1e12,
        b_wkr: 12.5e9,
    };
    let mut results = Vec::new();
    for racks in [2usize, 4] {
        let _ = bench_flat(racks, WORKERS_PER_RACK); // warm-up
        let flat = bench_flat(racks, WORKERS_PER_RACK);
        let _ = bench_two_level(racks, WORKERS_PER_RACK); // warm-up
        let two = bench_two_level(racks, WORKERS_PER_RACK);
        let predicted = hierarchical_beneficial(fat_core, WORKERS_PER_RACK, racks);
        println!(
            "  {racks} racks x {WORKERS_PER_RACK}: flat {flat:>7.1} rounds/s, \
             two-level {two:>7.1} rounds/s ({:.2}x, model predicts \
             hierarchical beneficial on fat core: {predicted})",
            two / flat
        );
        assert!(
            !predicted,
            "cost model must not favor hierarchy over a fat core"
        );
        results.push((racks, flat, two));
    }
    println!("hierarchy OK");
    // Single-line JSON summary for BENCH_hierarchy.json (keep last on
    // stdout).
    println!(
        "{{\"bench\":\"hierarchy\",\"workers_per_rack\":{WORKERS_PER_RACK},\
         \"chunks\":{CHUNKS},\"chunk_elems\":{CHUNK_ELEMS},\"rounds\":{ROUNDS},\
         \"flat2_rps\":{:.1},\"two_level2_rps\":{:.1},\
         \"flat4_rps\":{:.1},\"two_level4_rps\":{:.1},\
         \"overhead2\":{:.3},\"overhead4\":{:.3}}}",
        results[0].1,
        results[0].2,
        results[1].1,
        results[1].2,
        results[0].1 / results[0].2,
        results[1].1 / results[1].2
    );
}
