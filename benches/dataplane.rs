//! End-to-end chunk-round data-plane bench (run via `cargo bench --bench
//! dataplane`).
//!
//! Measures the leader-shaped push → aggregate → fused-optimize → reply
//! path over pre-encoded wire frames, comparing:
//!
//! * **vec path** — the pre-refactor shape: owning `read_frame`,
//!   `bytes_to_f32s` into a fresh `Vec<f32>`, slice absorb, unfused
//!   `take_mean` + optimizer step, reply via `f32s_to_bytes`.
//! * **pooled path** — the allocation-free shape: pooled
//!   `read_frame_into`, byte-level absorb fold, fused
//!   `take_mean_into_step` + `step_scaled`, reply serialized straight
//!   from a pooled parameter buffer.
//!
//! Reports aggregation throughput (gradient GB/s) and allocations per
//! round via a counting global allocator, then emits a single-line JSON
//! summary (last stdout line) suitable for `BENCH_dataplane.json`
//! trajectory tracking.
//!
//! Results feed EXPERIMENTS.md section Perf.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Cursor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use phub::coordinator::aggregation::{ChunkAggregator, GradSrc};
use phub::coordinator::engine::{
    single_lane_fabrics, PushOutcome, Reply, ReplyRx, RoundTag, ShardEngine,
};
use phub::coordinator::optimizer::{NesterovSgd, Optimizer};
use phub::coordinator::pool::{BytePool, Pool};
use phub::coordinator::wire::{self, Op};
use phub::prop::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const JOB: u32 = 1;
const WORKERS: usize = 4;
const CHUNKS: usize = 32;
const CHUNK_ELEMS: usize = 8192;
const ROUNDS: usize = 30;

/// One round of worker-major PushChunk frames as raw wire bytes.
fn encode_round(rng: &mut Rng) -> Vec<u8> {
    let mut out = Vec::new();
    for w in 0..WORKERS {
        for c in 0..CHUNKS {
            let grad = rng.vec_f32(CHUNK_ELEMS, 1.0);
            wire::write_chunk_frame_f32s(
                &mut out,
                Op::PushChunk,
                JOB,
                w as u32,
                c as u32,
                0,
                (c * CHUNK_ELEMS) as u64,
                &grad,
            )
            .unwrap();
        }
    }
    out
}

fn engine_with_job() -> (ShardEngine, Vec<ReplyRx>) {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..CHUNKS)
        .map(|c| (c as u32, vec![0.1f32; CHUNK_ELEMS]))
        .collect();
    let (txs, rxs) = single_lane_fabrics(JOB, WORKERS, 16);
    eng.init_job(
        JOB,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        WORKERS,
        txs,
    );
    (eng, rxs)
}

/// The pre-refactor path: every frame decoded into fresh vectors, mean
/// and optimizer as two separate passes, replies via `f32s_to_bytes`.
fn bench_vec_path(frames: &[u8]) -> (f64, f64) {
    let opt = NesterovSgd {
        lr: 0.01,
        momentum: 0.9,
    };
    let mut aggs: Vec<ChunkAggregator> = (0..CHUNKS)
        .map(|_| ChunkAggregator::new(CHUNK_ELEMS, WORKERS))
        .collect();
    let mut params = vec![0.1f32; CHUNKS * CHUNK_ELEMS];
    let mut state = vec![0.0f32; CHUNKS * CHUNK_ELEMS];
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..ROUNDS {
        let mut cur = Cursor::new(frames);
        for _ in 0..WORKERS * CHUNKS {
            let f = wire::read_frame(&mut cur).unwrap();
            let (chunk, _epoch, _off, bytes) = wire::decode_chunk_payload(&f.payload).unwrap();
            let grad = wire::bytes_to_f32s(bytes).unwrap();
            let ci = chunk as usize;
            let done = aggs[ci].absorb(f.worker as usize, &grad).unwrap();
            if done {
                let lo = ci * CHUNK_ELEMS;
                let hi = lo + CHUNK_ELEMS;
                let mean: Vec<f32> = aggs[ci].take_mean().unwrap().to_vec();
                opt.step(&mut params[lo..hi], &mut state[lo..hi], &mean);
                let _reply = wire::f32s_to_bytes(&params[lo..hi]);
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / ROUNDS as f64;
    (dt, allocs)
}

/// The pooled path: exactly the steady-state leader loop as deployed
/// (see `rust/tests/alloc_discipline.rs`, which asserts its allocation
/// count is zero) — worker 0 pulls, so each completion broadcasts one
/// refcount-shared parameter buffer over a real SPSC reply ring and the
/// reply frame serializes straight out of it. One reply serialization
/// per completion, matching the vec path's reply leg.
fn bench_pooled_path(frames: &[u8]) -> (f64, f64) {
    let (mut eng, mut rxs) = engine_with_job();
    let pool: Arc<BytePool> = Pool::new(16);
    let mut ready: Vec<u8> = Vec::new();
    // Warm the pools and slot state with one untimed round.
    run_pooled_round(frames, &mut eng, &pool, &mut rxs, &mut ready, 0);
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for r in 0..ROUNDS {
        run_pooled_round(frames, &mut eng, &pool, &mut rxs, &mut ready, (r + 1) as u64);
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / ROUNDS as f64;
    (dt, allocs)
}

fn run_pooled_round(
    frames: &[u8],
    eng: &mut ShardEngine,
    pool: &Arc<BytePool>,
    rxs: &mut [ReplyRx],
    ready: &mut Vec<u8>,
    round: u64,
) {
    let tag = RoundTag::new(0, round);
    let mut cur = Cursor::new(frames);
    for _ in 0..WORKERS * CHUNKS {
        let mut fb = pool.take();
        let (chunk, worker) = {
            let v = wire::read_frame_into(&mut cur, &mut fb).unwrap();
            let (chunk, _epoch, _off, _bytes) = wire::decode_chunk_payload(v.payload).unwrap();
            (chunk, v.worker)
        };
        let bytes = &fb[wire::CHUNK_PREFIX_BYTES..];
        let outcome = eng
            .push_src(JOB, chunk, worker, GradSrc::LeBytes(bytes), worker == 0, tag)
            .unwrap();
        if outcome == PushOutcome::Completed {
            // Reply leg as deployed: drain worker 0's ring and serialize
            // the ModelChunk frame out of the shared broadcast buffer.
            match rxs[0].try_recv() {
                Some(Reply::Chunk {
                    chunk, epoch, data, ..
                }) => {
                    ready.clear();
                    wire::write_chunk_frame_f32s(
                        ready,
                        Op::ModelChunk,
                        JOB,
                        0,
                        chunk,
                        epoch,
                        chunk as u64 * CHUNK_ELEMS as u64,
                        &data,
                    )
                    .unwrap();
                }
                other => panic!("expected worker 0's reply, got {other:?}"),
            }
        }
    }
}

fn main() {
    let grad_bytes_per_round = (WORKERS * CHUNKS * CHUNK_ELEMS * 4) as f64;
    println!(
        "== dataplane: {CHUNKS} x {CHUNK_ELEMS}-elem chunks ({} MB model), \
         {WORKERS} workers, {ROUNDS} rounds ==",
        CHUNKS * CHUNK_ELEMS * 4 >> 20
    );
    let mut rng = Rng::new(11);
    let frames = encode_round(&mut rng);

    // Interleave warm-up and measurement so both paths see warm caches.
    let _ = bench_vec_path(&frames);
    let (vec_dt, vec_allocs) = bench_vec_path(&frames);
    let _ = bench_pooled_path(&frames);
    let (pooled_dt, pooled_allocs) = bench_pooled_path(&frames);

    let gbps = |dt: f64| grad_bytes_per_round * ROUNDS as f64 / dt / 1e9;
    let vec_gbps = gbps(vec_dt);
    let pooled_gbps = gbps(pooled_dt);
    println!(
        "  vec path    (read_frame + bytes_to_f32s + unfused): \
         {vec_gbps:>7.2} GB/s, {vec_allocs:>8.1} allocs/round"
    );
    println!(
        "  pooled path (read_frame_into + byte fold + fused):  \
         {pooled_gbps:>7.2} GB/s, {pooled_allocs:>8.1} allocs/round"
    );
    println!(
        "  speedup: {:+.1}%  alloc reduction: {:.1}x",
        (pooled_gbps / vec_gbps - 1.0) * 100.0,
        if pooled_allocs > 0.0 {
            vec_allocs / pooled_allocs
        } else {
            f64::INFINITY
        }
    );
    println!("dataplane OK");
    // Single-line JSON summary for BENCH_dataplane.json trajectory
    // tracking (keep last on stdout).
    println!(
        "{{\"bench\":\"dataplane\",\"chunks\":{CHUNKS},\"chunk_elems\":{CHUNK_ELEMS},\
         \"workers\":{WORKERS},\"rounds\":{ROUNDS},\
         \"vec_gbps\":{vec_gbps:.3},\"pooled_gbps\":{pooled_gbps:.3},\
         \"vec_allocs_per_round\":{vec_allocs:.1},\
         \"pooled_allocs_per_round\":{pooled_allocs:.1}}}"
    );
}
