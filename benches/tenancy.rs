//! Tenancy fairness bench (run via `cargo bench --bench tenancy`): what
//! weighted-fair core scheduling buys a small tenant sharing a leader
//! with a flooding neighbor.
//!
//! Three arms, all measuring the same 1-worker victim job through the
//! in-process multi-core server (no TCP, so the number isolates the
//! core scheduler, not socket noise):
//!
//! * **solo** — the victim alone on the leader: the no-contention
//!   ceiling.
//! * **off**  — [`QuotaConfig::fair_sched`] disabled (legacy greedy
//!   per-port sweep) while [`FLOOD_JOBS`] single-worker tenants hammer
//!   models [`FLOOD_ELEMS`]`/`[`VICTIM_ELEMS`]`x` larger as fast as
//!   they can.
//! * **on**   — the same contention under deficit-round-robin with the
//!   victim weighted [`VICTIM_WEIGHT`]`:1`.
//!
//! Reported per arm: victim rounds/s and client-observed p99 round
//! latency. The fairness story is `on` holding closer to `solo` than
//! `off` does — but that is a *trajectory* observation, not a gate
//! (shared CI runners are noisy; `tools/bench_diff.py` only warns on
//! numeric drift).
//!
//! Emits a single-line JSON summary (last stdout line) suitable for
//! `BENCH_tenancy.json` trajectory tracking.

use std::hint::black_box;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use phub::config::QuotaConfig;
use phub::coordinator::optimizer::NesterovSgd;
use phub::coordinator::server::{PHubServer, ServerConfig};
use phub::coordinator::KeyTable;

const CORES: usize = 2;
const VICTIM_ELEMS: usize = 8 * 1024;
/// Each flooder round sweeps 16x the victim's model.
const FLOOD_ELEMS: usize = 128 * 1024;
const CHUNK_ELEMS: usize = 2 * 1024;
const FLOOD_JOBS: usize = 2;
const VICTIM_WEIGHT: u32 = 8;
const WARM_ROUNDS: usize = 10;
const ROUNDS: usize = 200;

fn opt() -> Arc<NesterovSgd> {
    Arc::new(NesterovSgd {
        lr: 0.01,
        momentum: 0.9,
    })
}

/// Victim (rounds/s, p99 ms) under one arm's configuration.
fn run_arm(fair: bool, flood: bool) -> (f64, f64) {
    let quota = QuotaConfig {
        fair_sched: fair,
        ..QuotaConfig::default()
    };
    let server = PHubServer::start(ServerConfig::cores(CORES).with_quota(quota));

    let init = vec![0.1f32; VICTIM_ELEMS];
    let victim_job = server.init_job_weighted(
        KeyTable::flat(VICTIM_ELEMS, CHUNK_ELEMS),
        &init,
        opt(),
        1,
        VICTIM_WEIGHT,
    );
    let mut victim = server.worker(victim_job, 0);

    // Flooders: single-worker jobs at weight 1, each free-running until
    // told to stop (single-worker so stopping needs no peer barrier).
    let stop = Arc::new(AtomicBool::new(false));
    let flooders: Vec<_> = (0..if flood { FLOOD_JOBS } else { 0 })
        .map(|_| {
            let flood_init = vec![0.1f32; FLOOD_ELEMS];
            let job = server.init_job_weighted(
                KeyTable::flat(FLOOD_ELEMS, CHUNK_ELEMS),
                &flood_init,
                opt(),
                1,
                1,
            );
            let mut h = server.worker(job, 0);
            let stop = stop.clone();
            std::thread::spawn(move || {
                let grad = vec![0.25f32; FLOOD_ELEMS];
                while !stop.load(Ordering::Relaxed) {
                    black_box(h.push_pull(&grad));
                }
            })
        })
        .collect();

    let grad = vec![0.5f32; VICTIM_ELEMS];
    for _ in 0..WARM_ROUNDS {
        black_box(victim.push_pull(&grad));
    }
    let mut lat_ms = Vec::with_capacity(ROUNDS);
    let t0 = Instant::now();
    for _ in 0..ROUNDS {
        let r0 = Instant::now();
        black_box(victim.push_pull(&grad));
        lat_ms.push(r0.elapsed().as_secs_f64() * 1e3);
    }
    let rps = ROUNDS as f64 / t0.elapsed().as_secs_f64();

    stop.store(true, Ordering::Relaxed);
    for f in flooders {
        f.join().unwrap();
    }
    drop(victim);
    PHubServer::shutdown(server);

    lat_ms.sort_by(|a, b| a.total_cmp(b));
    let p99 = lat_ms[((ROUNDS as f64 * 0.99).ceil() as usize - 1).min(ROUNDS - 1)];
    (rps, p99)
}

fn main() {
    println!(
        "== tenancy: {VICTIM_ELEMS}-elem victim (weight {VICTIM_WEIGHT}) vs \
         {FLOOD_JOBS} x {FLOOD_ELEMS}-elem flooders, {CORES} cores, {ROUNDS} rounds =="
    );
    let (solo_rps, solo_p99) = run_arm(true, false);
    println!("  solo      {solo_rps:>9.1} rounds/s  p99 {solo_p99:>7.3} ms");
    let (off_rps, off_p99) = run_arm(false, true);
    println!("  fair off  {off_rps:>9.1} rounds/s  p99 {off_p99:>7.3} ms");
    let (on_rps, on_p99) = run_arm(true, true);
    println!(
        "  fair on   {on_rps:>9.1} rounds/s  p99 {on_p99:>7.3} ms  \
         (keeps {:.0}% of solo vs {:.0}% with fairness off)",
        100.0 * on_rps / solo_rps,
        100.0 * off_rps / solo_rps
    );
    println!("tenancy OK");

    // Single-line JSON summary for BENCH_tenancy.json trajectory
    // tracking (keep last on stdout).
    println!(
        "{{\"bench\":\"tenancy\",\"cores\":{CORES},\"victim_elems\":{VICTIM_ELEMS},\
         \"flood_elems\":{FLOOD_ELEMS},\"chunk_elems\":{CHUNK_ELEMS},\
         \"flood_jobs\":{FLOOD_JOBS},\"victim_weight\":{VICTIM_WEIGHT},\
         \"rounds\":{ROUNDS},\"solo_rps\":{solo_rps:.3},\"solo_p99_ms\":{solo_p99:.4},\
         \"off_rps\":{off_rps:.3},\"off_p99_ms\":{off_p99:.4},\
         \"on_rps\":{on_rps:.3},\"on_p99_ms\":{on_p99:.4}}}"
    );
}
