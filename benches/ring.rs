//! Queue-fabric microbench (run via `cargo bench --bench ring`).
//!
//! Three measurements, matching the tentpole's two claims:
//!
//! 1. **Ping-pong**: one message bouncing between two threads — SPSC
//!    ring pair vs `std::sync::mpsc` channel pair. Latency-shaped: this
//!    is where mpsc's receiver lock and park-heavy blocking hurt, and
//!    where the ring's spin-then-park wait pays off.
//! 2. **Fan-in**: 4 producer threads streaming into one consumer —
//!    4 SPSC rings behind one shared waiter (the core's port-mesh
//!    shape) vs 4 cloned mpsc senders into one receiver.
//!    Throughput-shaped: the ring consumer takes no lock and the
//!    producers never contend with each other.
//! 3. **Reply broadcast**: end-to-end engine rounds/s at 1/4/8 pulling
//!    workers, single-copy (the deployed refcount-shared broadcast —
//!    one parameter copy per completion regardless of puller count)
//!    vs per-puller-copy (the pre-refactor shape: one exclusive pooled
//!    copy per puller). Both sides serialize one wire frame per puller,
//!    so the delta isolates the copy fan-out on the core.
//!
//! Emits a single-line JSON summary (last stdout line) for
//! `BENCH_ring.json` trajectory tracking. Results feed EXPERIMENTS.md
//! section Perf.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use phub::coordinator::engine::{
    single_lane_fabrics, PushOutcome, Reply, ReplyRx, RoundTag, ShardEngine,
};
use phub::coordinator::optimizer::NesterovSgd;
use phub::coordinator::pool::{F32Pool, Pool};
use phub::coordinator::ring;
use phub::coordinator::wire::{self, Op};
use phub::prop::Rng;

const PINGPONG_ROUNDTRIPS: usize = 200_000;
const FANIN_PRODUCERS: usize = 4;
const FANIN_MSGS_EACH: usize = 250_000;

const JOB: u32 = 1;
const CHUNKS: usize = 16;
const CHUNK_ELEMS: usize = 4096;
const BROADCAST_ROUNDS: usize = 40;

/// Ring ping-pong: a token bounces A→B→A `n` times. Returns round trips
/// per second.
fn ring_pingpong(n: usize) -> f64 {
    let (tx_ab, rx_ab) = ring::spsc::<u64>(4);
    let (tx_ba, rx_ba) = ring::spsc::<u64>(4);
    let echo = std::thread::spawn(move || {
        while let Ok(v) = rx_ab.recv() {
            if tx_ba.send(v).is_err() {
                break;
            }
        }
    });
    let t0 = Instant::now();
    for i in 0..n as u64 {
        tx_ab.send(i).unwrap();
        assert_eq!(rx_ba.recv().unwrap(), i);
    }
    let dt = t0.elapsed().as_secs_f64();
    drop(tx_ab);
    echo.join().unwrap();
    n as f64 / dt
}

/// `std::sync::mpsc` ping-pong with the same shape.
fn mpsc_pingpong(n: usize) -> f64 {
    let (tx_ab, rx_ab) = mpsc::channel::<u64>();
    let (tx_ba, rx_ba) = mpsc::channel::<u64>();
    let echo = std::thread::spawn(move || {
        while let Ok(v) = rx_ab.recv() {
            if tx_ba.send(v).is_err() {
                break;
            }
        }
    });
    let t0 = Instant::now();
    for i in 0..n as u64 {
        tx_ab.send(i).unwrap();
        assert_eq!(rx_ba.recv().unwrap(), i);
    }
    let dt = t0.elapsed().as_secs_f64();
    drop(tx_ab);
    echo.join().unwrap();
    n as f64 / dt
}

/// Ring fan-in: `p` producer threads each send `each` messages over
/// their own SPSC ring; one consumer drains all rings behind one shared
/// waiter (the core port-mesh shape). Returns messages per second.
fn ring_fanin(p: usize, each: usize) -> f64 {
    let waiter = Arc::new(ring::Waiter::new());
    let mut txs = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..p {
        let (tx, rx) = ring::spsc_shared::<u64>(1024, waiter.clone());
        txs.push(tx);
        rxs.push(rx);
    }
    let producers: Vec<_> = txs
        .into_iter()
        .map(|tx| {
            std::thread::spawn(move || {
                for i in 0..each as u64 {
                    tx.send(i).unwrap();
                }
            })
        })
        .collect();
    let total = p * each;
    let t0 = Instant::now();
    let mut got = 0usize;
    let mut sum = 0u64;
    while got < total {
        let mut idle = true;
        for rx in &rxs {
            while let Ok(v) = rx.try_recv() {
                sum = sum.wrapping_add(v);
                got += 1;
                idle = false;
            }
        }
        if idle {
            waiter.wait_until(|| rxs.iter().any(|r| !r.is_empty()));
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(
        sum,
        (p as u64) * (each as u64 * (each as u64 - 1) / 2),
        "fan-in lost or duplicated messages"
    );
    for h in producers {
        h.join().unwrap();
    }
    total as f64 / dt
}

/// `std::sync::mpsc` fan-in with the same shape (cloned senders).
fn mpsc_fanin(p: usize, each: usize) -> f64 {
    let (tx, rx) = mpsc::channel::<u64>();
    let producers: Vec<_> = (0..p)
        .map(|_| {
            let tx = tx.clone();
            std::thread::spawn(move || {
                for i in 0..each as u64 {
                    tx.send(i).unwrap();
                }
            })
        })
        .collect();
    drop(tx);
    let total = p * each;
    let t0 = Instant::now();
    let mut sum = 0u64;
    for _ in 0..total {
        sum = sum.wrapping_add(rx.recv().unwrap());
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(sum, (p as u64) * (each as u64 * (each as u64 - 1) / 2));
    for h in producers {
        h.join().unwrap();
    }
    total as f64 / dt
}

fn broadcast_engine(pullers: usize) -> (ShardEngine, Vec<ReplyRx>) {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..CHUNKS)
        .map(|c| (c as u32, vec![0.1f32; CHUNK_ELEMS]))
        .collect();
    let (txs, rxs) = single_lane_fabrics(JOB, pullers, 32);
    eng.init_job(
        JOB,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        pullers,
        txs,
    );
    (eng, rxs)
}

/// End-to-end rounds/s with the deployed single-copy broadcast: every
/// worker pulls, the engine copies each completed chunk once into a
/// shared buffer, and each worker's lane serializes its frame out of it.
fn bench_broadcast_shared(pullers: usize, grads: &[Vec<f32>]) -> f64 {
    let (mut eng, mut rxs) = broadcast_engine(pullers);
    let mut ready: Vec<Vec<u8>> = vec![Vec::new(); pullers];
    let run = |eng: &mut ShardEngine, rxs: &mut [ReplyRx], ready: &mut [Vec<u8>], r: u64| {
        let tag = RoundTag::new(0, r);
        for c in 0..CHUNKS as u32 {
            for (w, g) in grads.iter().enumerate().take(pullers) {
                let lo = c as usize * CHUNK_ELEMS;
                let outcome = eng
                    .push_src(
                        JOB,
                        c,
                        w as u32,
                        phub::coordinator::GradSrc::F32s(&g[lo..lo + CHUNK_ELEMS]),
                        true,
                        tag,
                    )
                    .unwrap();
                if outcome == PushOutcome::Completed {
                    for (i, rx) in rxs.iter_mut().enumerate() {
                        match rx.try_recv() {
                            Some(Reply::Chunk { chunk, epoch, data, .. }) => {
                                ready[i].clear();
                                wire::write_chunk_frame_f32s(
                                    &mut ready[i],
                                    Op::ModelChunk,
                                    JOB,
                                    i as u32,
                                    chunk,
                                    epoch,
                                    lo as u64,
                                    &data,
                                )
                                .unwrap();
                            }
                            other => panic!("expected reply, got {other:?}"),
                        }
                    }
                }
            }
        }
    };
    run(&mut eng, &mut rxs, &mut ready, 0); // warm
    let t0 = Instant::now();
    for r in 0..BROADCAST_ROUNDS {
        run(&mut eng, &mut rxs, &mut ready, (r + 1) as u64);
    }
    BROADCAST_ROUNDS as f64 / t0.elapsed().as_secs_f64()
}

/// The pre-refactor reply shape: on each completion the core copies the
/// parameters into one exclusive pooled buffer **per puller** before the
/// per-puller serialization. Same engine, same serialization work — the
/// delta is the copy fan-out.
fn bench_broadcast_copy_per_puller(pullers: usize, grads: &[Vec<f32>]) -> f64 {
    let (mut eng, _rxs) = broadcast_engine(pullers);
    let fpool: Arc<F32Pool> = Pool::new(64);
    let mut ready: Vec<Vec<u8>> = vec![Vec::new(); pullers];
    let run = |eng: &mut ShardEngine, ready: &mut [Vec<u8>], r: u64| {
        let tag = RoundTag::new(0, r);
        for c in 0..CHUNKS as u32 {
            for (w, g) in grads.iter().enumerate().take(pullers) {
                let lo = c as usize * CHUNK_ELEMS;
                let outcome = eng
                    .push_src(
                        JOB,
                        c,
                        w as u32,
                        phub::coordinator::GradSrc::F32s(&g[lo..lo + CHUNK_ELEMS]),
                        false,
                        tag,
                    )
                    .unwrap();
                if outcome == PushOutcome::Completed {
                    let params = eng.chunk_params(JOB, c).unwrap();
                    for (i, rd) in ready.iter_mut().enumerate() {
                        let mut buf = fpool.take();
                        buf.extend_from_slice(params); // per-puller copy
                        rd.clear();
                        wire::write_chunk_frame_f32s(
                            rd,
                            Op::ModelChunk,
                            JOB,
                            i as u32,
                            c,
                            0,
                            lo as u64,
                            &buf,
                        )
                        .unwrap();
                    }
                }
            }
        }
    };
    run(&mut eng, &mut ready, 0); // warm
    let t0 = Instant::now();
    for r in 0..BROADCAST_ROUNDS {
        run(&mut eng, &mut ready, (r + 1) as u64);
    }
    BROADCAST_ROUNDS as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== ring fabric: SPSC ring vs std::sync::mpsc ==");
    // Interleave warm-up and measurement so both see warm caches.
    let _ = ring_pingpong(PINGPONG_ROUNDTRIPS / 10);
    let _ = mpsc_pingpong(PINGPONG_ROUNDTRIPS / 10);
    let ring_pp = ring_pingpong(PINGPONG_ROUNDTRIPS);
    let mpsc_pp = mpsc_pingpong(PINGPONG_ROUNDTRIPS);
    println!(
        "  ping-pong:  ring {:>9.0} rt/s   mpsc {:>9.0} rt/s   ({:.2}x)",
        ring_pp,
        mpsc_pp,
        ring_pp / mpsc_pp
    );

    let _ = ring_fanin(FANIN_PRODUCERS, FANIN_MSGS_EACH / 10);
    let _ = mpsc_fanin(FANIN_PRODUCERS, FANIN_MSGS_EACH / 10);
    let ring_fi = ring_fanin(FANIN_PRODUCERS, FANIN_MSGS_EACH);
    let mpsc_fi = mpsc_fanin(FANIN_PRODUCERS, FANIN_MSGS_EACH);
    println!(
        "  fan-in x{FANIN_PRODUCERS}:  ring {:>9.0} msg/s  mpsc {:>9.0} msg/s  ({:.2}x)",
        ring_fi,
        mpsc_fi,
        ring_fi / mpsc_fi
    );

    println!(
        "== reply broadcast: {CHUNKS} x {CHUNK_ELEMS}-elem chunks, \
         {BROADCAST_ROUNDS} rounds =="
    );
    let mut rng = Rng::new(17);
    let grads: Vec<Vec<f32>> = (0..8).map(|_| rng.vec_f32(CHUNKS * CHUNK_ELEMS, 1.0)).collect();
    let chunk_bytes = CHUNK_ELEMS * 4;
    let mut shared_rps = Vec::new();
    let mut copy_rps = Vec::new();
    for &p in &[1usize, 4, 8] {
        let s = bench_broadcast_shared(p, &grads);
        let c = bench_broadcast_copy_per_puller(p, &grads);
        shared_rps.push((p, s));
        copy_rps.push((p, c));
        println!(
            "  {p} puller(s): single-copy {s:>8.1} rounds/s \
             ({chunk_bytes} B copied/completion), per-puller-copy \
             {c:>8.1} rounds/s ({} B copied/completion)",
            p * chunk_bytes
        );
    }
    println!("ring OK");
    // Single-line JSON summary for BENCH_ring.json (keep last on stdout).
    println!(
        "{{\"bench\":\"ring\",\
         \"pingpong_roundtrips\":{PINGPONG_ROUNDTRIPS},\
         \"ring_pingpong_rts\":{ring_pp:.0},\"mpsc_pingpong_rts\":{mpsc_pp:.0},\
         \"pingpong_speedup\":{:.3},\
         \"fanin_producers\":{FANIN_PRODUCERS},\"fanin_msgs_each\":{FANIN_MSGS_EACH},\
         \"ring_fanin_mps\":{ring_fi:.0},\"mpsc_fanin_mps\":{mpsc_fi:.0},\
         \"fanin_speedup\":{:.3},\
         \"chunk_bytes\":{chunk_bytes},\
         \"shared_rps_1\":{:.1},\"shared_rps_4\":{:.1},\"shared_rps_8\":{:.1},\
         \"copy_rps_1\":{:.1},\"copy_rps_4\":{:.1},\"copy_rps_8\":{:.1},\
         \"shared_copied_bytes_per_completion\":{chunk_bytes},\
         \"copy_copied_bytes_per_completion_8p\":{}}}",
        ring_pp / mpsc_pp,
        ring_fi / mpsc_fi,
        shared_rps[0].1,
        shared_rps[1].1,
        shared_rps[2].1,
        copy_rps[0].1,
        copy_rps[1].1,
        copy_rps[2].1,
        8 * chunk_bytes
    );
}
