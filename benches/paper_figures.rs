//! Regenerates every FIGURE in the paper's evaluation (run via
//! `cargo bench --bench paper_figures`). One section per figure; each
//! prints the same series the paper plots, with the paper's qualitative
//! claim quoted for comparison. EXPERIMENTS.md records the deltas.

use phub::compute::Gpu;
use phub::collectives::{self, AlphaBeta};
use phub::config::{ClusterConfig, ExchangeConfig, NetConfig, PsConfig, Stack};
use phub::coordinator::hierarchy;
use phub::dnn::Dnn;
use phub::memmodel::PcieBridge;
use phub::sim::{self, SimOpts};

fn testbed() -> ClusterConfig {
    ClusterConfig::paper_testbed()
}

fn mxnet_tcp(net: NetConfig) -> ClusterConfig {
    testbed()
        .with_ps(PsConfig::ColocatedSharded)
        .with_stack(Stack::MxnetTcp)
        .with_net(net)
        .with_exchange(ExchangeConfig::mxnet())
}

fn mxnet_ib(net: NetConfig) -> ClusterConfig {
    mxnet_tcp(net).with_stack(Stack::MxnetIb)
}

/// Figure 2: distributed-vs-local throughput ratio falls as GPUs get
/// faster ("with today's fast GPUs, training time is chiefly spent
/// waiting for parameter exchanges").
fn fig2() {
    println!("== Fig 2: overhead grows with GPU generation (10G, MXNet TCP) ==");
    for abbrev in ["AN", "RN269", "GN", "I3"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        print!("  {abbrev:<6}");
        for gpu in Gpu::GENERATIONS {
            let r = sim::simulate(&mxnet_tcp(NetConfig::cloud_10g()), &d, gpu);
            let local = d.batch as f64 / (d.time_per_batch / gpu.speedup());
            let ratio = r.throughput / (8.0 * local);
            print!("  {}={:.0}%", gpu.label().split(' ').next().unwrap(), ratio * 100.0);
        }
        println!();
    }
    println!("  (paper: ratio collapses for fast GPUs; compute no longer hides comm)");
}

/// Figure 5 / Figure 14: progressive overhead breakdown, MXNet vs PHub.
fn fig5_fig14() {
    println!("\n== Fig 5: progressive overhead breakdown, MXNet TCP 56G (ms/iter) ==");
    let nets = ["RN269", "RX269", "I3", "GN", "RN50", "RN18", "V19", "V11", "AN"];
    println!(
        "  {:<7} {:>8} {:>10} {:>7} {:>7} {:>7} {:>7}",
        "net", "compute", "copy+comm", "agg", "opt", "sync", "ovh%"
    );
    for abbrev in nets {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let net = mxnet_tcp(NetConfig::infiniband_56g());
        let b = sim::breakdown::progressive(&net, &d, Gpu::Gtx1080Ti);
        println!(
            "  {:<7} {:>8.1} {:>10.1} {:>7.1} {:>7.1} {:>7.1} {:>6.0}%",
            abbrev,
            b.compute * 1e3,
            b.data_copy_comm * 1e3,
            b.aggregation * 1e3,
            b.optimization * 1e3,
            b.sync_other * 1e3,
            b.overhead_share() * 100.0
        );
    }
    println!("\n== Fig 14: same, PHub/PBox ('GPU compute now dominates') ==");
    println!(
        "  {:<7} {:>8} {:>10} {:>7} {:>7} {:>7} {:>7}",
        "net", "compute", "copy+comm", "agg", "opt", "sync", "ovh%"
    );
    for abbrev in nets {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let b = sim::breakdown::progressive(&testbed(), &d, Gpu::Gtx1080Ti);
        println!(
            "  {:<7} {:>8.1} {:>10.1} {:>7.1} {:>7.1} {:>7.1} {:>6.0}%",
            abbrev,
            b.compute * 1e3,
            b.data_copy_comm * 1e3,
            b.aggregation * 1e3,
            b.optimization * 1e3,
            b.sync_other * 1e3,
            b.overhead_share() * 100.0
        );
    }
}

/// Figure 11: speedup from the zero-copy IB data plane alone (MXNet IB vs
/// MXNet TCP, PS architecture unchanged).
fn fig11() {
    println!("\n== Fig 11: speedup from a faster data plane (MXNet IB / MXNet TCP, 56G) ==");
    for abbrev in ["AN", "V11", "V19", "GN", "I3", "RN18", "RN50", "RN269", "RX269"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let tcp = sim::simulate(&mxnet_tcp(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        let ib = sim::simulate(&mxnet_ib(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        println!("  {abbrev:<6} {:.2}x", ib.throughput / tcp.throughput);
    }
}

/// Figure 12: training on a cloud-like 10 Gbps network, normalized to the
/// enhanced baseline (sharded MXNet IB). Paper: PBox up to 2.7x.
fn fig12() {
    println!("\n== Fig 12: 10 Gbps training speedup vs MXNet IB (paper: up to 2.7x) ==");
    println!("  {:<7} {:>9} {:>9} {:>9}", "net", "PShard", "PBox", "PBox(7w)");
    for abbrev in ["AN", "V11", "V19", "GN", "I3", "RN18", "RN50", "RN269", "RX269"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let base = sim::simulate(&mxnet_ib(NetConfig::cloud_10g()), &d, Gpu::Gtx1080Ti);
        let pshard = sim::simulate(
            &testbed()
                .with_ps(PsConfig::ColocatedSharded)
                .with_net(NetConfig::cloud_10g()),
            &d,
            Gpu::Gtx1080Ti,
        );
        let pbox = sim::simulate(&testbed().with_net(NetConfig::cloud_10g()), &d, Gpu::Gtx1080Ti);
        let pbox7 = sim::simulate(
            &testbed().with_net(NetConfig::cloud_10g()).with_workers(7),
            &d,
            Gpu::Gtx1080Ti,
        );
        println!(
            "  {:<7} {:>8.2}x {:>8.2}x {:>8.2}x",
            abbrev,
            pshard.throughput / base.throughput,
            pbox.throughput / base.throughput,
            // Per-machine-count-normalized: 7 workers + PBox = 8 machines.
            (pbox7.throughput / 7.0) / (base.throughput / 8.0)
        );
    }
}

/// Figure 13: 56 Gbps network. Paper: only AN/VGG stay network-bound;
/// ResNet/GoogleNet/Inception see ~1x (omitted there, checked here).
fn fig13() {
    println!("\n== Fig 13: 56 Gbps training speedup vs MXNet IB ==");
    for abbrev in ["AN", "V11", "V19", "GN", "I3", "RN50", "RN269"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let base = sim::simulate(&mxnet_ib(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        let pbox = sim::simulate(&testbed(), &d, Gpu::Gtx1080Ti);
        println!("  {abbrev:<6} {:.2}x", pbox.throughput / base.throughput);
    }
}

/// Figure 15: ZeroComputeEngine scaling — PBox linear to 8 workers,
/// baselines flat (paper: up to 40x).
fn fig15() {
    println!("\n== Fig 15: exchanges/s with infinitely fast compute (RN18, 56G) ==");
    let d = Dnn::by_abbrev("RN18").unwrap();
    println!(
        "  {:<3} {:>10} {:>10} {:>11} {:>11}",
        "n", "PBox", "PShard", "MXNet IB", "MXNet TCP"
    );
    for n in [1usize, 2, 4, 8] {
        let pbox = sim::simulate(&testbed().with_workers(n), &d, Gpu::ZeroCompute);
        let pshard = sim::simulate(
            &testbed().with_ps(PsConfig::ColocatedSharded).with_workers(n),
            &d,
            Gpu::ZeroCompute,
        );
        let ib = sim::simulate(
            &mxnet_ib(NetConfig::infiniband_56g()).with_workers(n),
            &d,
            Gpu::ZeroCompute,
        );
        let tcp = sim::simulate(
            &mxnet_tcp(NetConfig::infiniband_56g()).with_workers(n),
            &d,
            Gpu::ZeroCompute,
        );
        // The paper plots total system exchange throughput.
        let nf = n as f64;
        println!(
            "  {:<3} {:>10.1} {:>10.1} {:>11.1} {:>11.1}",
            n,
            pbox.exchange_rate * nf,
            pshard.exchange_rate * nf,
            ib.exchange_rate * nf,
            tcp.exchange_rate * nf
        );
    }
}

/// Section 4.5: key affinity (Key-by-Interface vs Worker-by-Interface,
/// paper 1.43x) — via the sim's locality model.
fn sec45_affinity() {
    println!("\n== Sec 4.5: key affinity, ZeroCompute RN18 (paper: KbI 1.43x WbI) ==");
    let d = Dnn::by_abbrev("RN18").unwrap();
    let kbi = sim::simulate(&testbed(), &d, Gpu::ZeroCompute);
    let mut wbi_cfg = testbed();
    wbi_cfg.exchange.key_by_interface = false;
    let wbi = sim::simulate(&wbi_cfg, &d, Gpu::ZeroCompute);
    println!(
        "  KbI {:.0} vs WbI {:.0} exchanges/s -> {:.2}x",
        kbi.exchange_rate,
        wbi.exchange_rate,
        kbi.exchange_rate / wbi.exchange_rate
    );
}

/// Figure 16: chunk size and queue pair count sweeps.
fn fig16() {
    println!("\n== Fig 16 (left): chunk size sweep, ZeroCompute RN18 (paper optimum 32KB) ==");
    let d = Dnn::by_abbrev("RN18").unwrap();
    for kb in [4usize, 8, 16, 32, 64, 128, 512, 2048] {
        let mut c = testbed();
        c.exchange.chunk_bytes = kb * 1024;
        let r = sim::simulate(&c, &d, Gpu::ZeroCompute);
        println!("  {kb:>5} KB  {:>8.1} exchanges/s", r.exchange_rate);
    }
    println!("== Fig 16 (right): QPs per connection (paper: fewer QPs win) ==");
    for qps in [1usize, 2, 4, 8, 16, 32] {
        let mut c = testbed();
        c.net.qps_per_connection = qps;
        let r = sim::simulate(&c, &d, Gpu::ZeroCompute);
        println!("  {qps:>3} QPs {:>8.1} exchanges/s", r.exchange_rate);
    }
}

/// Figure 17: PBox scalability vs the PCIe-to-memory bridge ceiling.
fn fig17() {
    println!("\n== Fig 17: PBox aggregate bandwidth vs emulated workers (GB/s) ==");
    println!(
        "  {:<3} {:>12} {:>12} {:>10}",
        "n", "IB/PCIe ideal", "microbench", "PHub (97%)"
    );
    let p = PcieBridge::pbox();
    for n in [2usize, 4, 8, 12, 16] {
        println!(
            "  {:<3} {:>12.1} {:>12.1} {:>10.1}",
            n,
            p.ideal_rate(n, 14e9) / 1e9,
            p.microbench_rate(n, 14e9) / 1e9,
            p.phub_rate(n, 14e9) / 1e9
        );
    }
    println!("  (paper: microbench and PHub plateau at ~90 GB/s, not NIC 140)");
}

/// Figure 18: multiple jobs sharing one PBox (simulated resource split).
fn fig18() {
    println!("\n== Fig 18: multi-tenant per-job throughput vs 1 job (10G) ==");
    println!("paper: AN -5% at 8 jobs, RN50 ~0%");
    for abbrev in ["AN", "RN50"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        print!("  {abbrev:<6}");
        let mut base = 0.0;
        for jobs in [1usize, 2, 4, 8] {
            let r = sim::simulate_opts(
                &testbed().with_net(NetConfig::cloud_10g()),
                &d,
                Gpu::Gtx1080Ti,
                SimOpts {
                    tenants: jobs,
                    ..SimOpts::default()
                },
            );
            if jobs == 1 {
                base = r.throughput;
            }
            // Per-job throughput x J vs the single-job run: isolates
            // PBox-sharing overhead from the unavoidable 1/J timeshare.
            print!("  {jobs}j={:.0}%", 100.0 * r.throughput * jobs as f64 / base);
        }
        println!();
    }
}

/// Figure 19: hierarchical reduction overhead vs racks.
fn fig19() {
    println!("\n== Fig 19: per-rack throughput with hierarchical reduction (10G) ==");
    println!("paper: AN loses throughput with racks; RN50 virtually none");
    for abbrev in ["AN", "RN50"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let local = sim::simulate(&testbed().with_net(NetConfig::cloud_10g()), &d, Gpu::Gtx1080Ti);
        print!("  {abbrev:<6}");
        let mut base = 0.0;
        for racks in [1usize, 2, 4, 8] {
            let tp = hierarchy::throughput_with_hierarchy(
                &d, racks, 8, local.iter_time, 32 * 1024, 10.0, 10e-6,
            ) / racks as f64;
            if racks == 1 {
                base = tp;
            }
            print!("  {racks}r={:.0}%", 100.0 * tp / base);
        }
        println!();
    }
}

/// Figure 20: PBox vs Gloo collectives (ring / recursive halving-doubling).
fn fig20() {
    println!("\n== Fig 20: exchange time models, RN50 (97MB), 8 nodes ==");
    let m = 97.0 * 1024.0 * 1024.0;
    for (name, gbps) in [("10G", 10.0), ("56G", 56.0)] {
        let ab = AlphaBeta {
            alpha: 10e-6,
            beta: 8.0 / (gbps * 1e9),
        };
        let ring = collectives::ring_time(ab, 8, m);
        let hd = collectives::halving_doubling_time(ab, 8, m);
        let pbox = collectives::central_ps_time(ab, 8, m, 10.0);
        println!(
            "  {name}: ring {:.1} ms | halving-doubling {:.1} ms | PBox {:.1} ms ({:.2}x vs HD)",
            ring * 1e3,
            hd * 1e3,
            pbox * 1e3,
            hd / pbox
        );
    }
    println!("  (paper: PBox ~2x faster than the best Gloo collective)");
}

fn main() {
    let t0 = std::time::Instant::now();
    fig2();
    fig5_fig14();
    fig11();
    fig12();
    fig13();
    fig15();
    sec45_affinity();
    fig16();
    fig17();
    fig18();
    fig19();
    fig20();
    println!("\n[paper_figures done in {:.1}s]", t0.elapsed().as_secs_f64());
}
