//! Chunk-streamed exchange granularity (run via `cargo bench --bench
//! wire_stream`).
//!
//! Measures synchronous round latency of the chunk-streamed wire protocol
//! on localhost TCP across model sizes, comparing the paper's multi-chunk
//! data plane against a single whole-model chunk — the shape the retired
//! v0 monolithic protocol had, which fully serializes network and
//! compute. Multi-chunk overlaps reception, aggregation, optimization,
//! and transmission per chunk (paper §3.2), so multi-chunk models should
//! round-trip no slower — and typically faster — than the single-chunk
//! baseline.
//!
//! Results feed EXPERIMENTS.md section Perf.

use std::time::Instant;

use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};

const CHUNK_ELEMS: usize = 8192;

/// Mean seconds per synchronous round across `workers` concurrent workers.
fn bench_chunking(
    addr: std::net::SocketAddr,
    job: u32,
    model: usize,
    chunk_elems: usize,
    workers: u32,
    rounds: usize,
) -> f64 {
    let spec = JobSpec {
        model_elems: model as u64,
        chunk_elems: chunk_elems as u64,
        n_workers: workers,
        lr: 0.1,
        momentum: 0.9,
    };
    let joins: Vec<_> = (0..workers)
        .map(|w| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect(addr, job, spec).unwrap();
                let grad: Vec<f32> = (0..model)
                    .map(|i| ((i + w as usize) % 7) as f32 * 0.1)
                    .collect();
                worker.push_pull(&grad).unwrap(); // warmup round
                let t0 = Instant::now();
                for _ in 0..rounds {
                    worker.push_pull(&grad).unwrap();
                }
                let dt = t0.elapsed().as_secs_f64();
                worker.bye();
                dt
            })
        })
        .collect();
    let total: f64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    total / workers as f64 / rounds as f64
}

fn main() {
    println!("== wire_stream: multi-chunk streamed vs single-chunk (v0-shaped) rounds ==");
    let workers = 2u32;
    let rounds = 20usize;
    let mut job = 1u32;
    for model_kb in [64usize, 1024, 4096, 16384] {
        let model = model_kb * 1024 / 4;
        let chunks = model.div_ceil(CHUNK_ELEMS);
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(4)).unwrap();
        let addr = leader.local_addr();
        let mono = bench_chunking(addr, job, model, model, workers, rounds);
        let streamed =
            bench_chunking(addr, job + 1, model, CHUNK_ELEMS.min(model), workers, rounds);
        job += 2;
        println!(
            "  {model_kb:>6} KB model ({chunks:>4} chunks, {workers} workers): \
             single-chunk {:>8.3} ms/round, streamed {:>8.3} ms/round ({:+5.1}%)",
            mono * 1e3,
            streamed * 1e3,
            (streamed / mono - 1.0) * 100.0
        );
    }
    println!("wire_stream OK");
}
