//! Streamed vs monolithic TCP exchange (run via `cargo bench --bench
//! wire_stream`).
//!
//! Measures synchronous round latency of the v1 chunk-streamed wire
//! protocol against the legacy v0 whole-frame protocol on localhost TCP,
//! across model sizes. The streamed path overlaps reception, aggregation,
//! optimization, and transmission per chunk (paper §3.2), so multi-chunk
//! models should round-trip no slower — and typically faster — than the
//! monolithic path, which fully serializes network and compute.
//!
//! Results feed EXPERIMENTS.md section Perf.

use std::time::Instant;

use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::coordinator::wire;

const CHUNK_ELEMS: usize = 8192;

/// Mean seconds per synchronous round across `workers` concurrent workers.
fn bench_proto(
    addr: std::net::SocketAddr,
    job: u32,
    model: usize,
    workers: u32,
    rounds: usize,
    proto: u32,
) -> f64 {
    let spec = JobSpec {
        model_elems: model as u64,
        chunk_elems: CHUNK_ELEMS.min(model) as u64,
        n_workers: workers,
        lr: 0.1,
        momentum: 0.9,
    };
    let joins: Vec<_> = (0..workers)
        .map(|w| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect_with_proto(addr, job, spec, proto).unwrap();
                assert_eq!(worker.proto(), proto);
                let grad: Vec<f32> = (0..model)
                    .map(|i| ((i + w as usize) % 7) as f32 * 0.1)
                    .collect();
                worker.push_pull(&grad).unwrap(); // warmup round
                let t0 = Instant::now();
                for _ in 0..rounds {
                    worker.push_pull(&grad).unwrap();
                }
                let dt = t0.elapsed().as_secs_f64();
                worker.bye();
                dt
            })
        })
        .collect();
    let total: f64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    total / workers as f64 / rounds as f64
}

fn main() {
    println!("== wire_stream: chunk-streamed (v1) vs monolithic (v0) rounds ==");
    let workers = 2u32;
    let rounds = 20usize;
    let mut job = 1u32;
    for model_kb in [64usize, 1024, 4096, 16384] {
        let model = model_kb * 1024 / 4;
        let chunks = model.div_ceil(CHUNK_ELEMS);
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 4 }).unwrap();
        let addr = leader.local_addr();
        let mono = bench_proto(addr, job, model, workers, rounds, wire::PROTO_MONOLITHIC);
        let streamed = bench_proto(addr, job + 1, model, workers, rounds, wire::PROTO_CHUNK_STREAMED);
        job += 2;
        println!(
            "  {model_kb:>6} KB model ({chunks:>4} chunks, {workers} workers): \
             monolithic {:>8.3} ms/round, streamed {:>8.3} ms/round ({:+5.1}%)",
            mono * 1e3,
            streamed * 1e3,
            (streamed / mono - 1.0) * 100.0
        );
    }
    println!("wire_stream OK");
}
