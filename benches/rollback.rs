//! Round-engine overhead microbench (run via `cargo bench --bench
//! rollback`).
//!
//! The engine refactor added explicit `(epoch, round)` tags and
//! `Result`-returning transitions to the per-chunk hot path. This bench
//! measures what that costs at steady state: rounds/sec of the
//! pre-refactor inner loop (raw `ChunkAggregator` absorb + mean + fused
//! optimizer step, no tags, no job lookup) against the same rounds driven
//! through `ShardEngine::push` with epoch tagging. Target: no measurable
//! regression — the tag checks are two integer compares per chunk push
//! against a memory-bandwidth-bound accumulate.
//!
//! Also reports the cost of the rollback transition itself (rewinding a
//! partially aggregated round), which sits on the recovery path, not the
//! hot path.
//!
//! Results feed EXPERIMENTS.md section Perf.

use std::sync::Arc;
use std::time::Instant;

use phub::coordinator::aggregation::ChunkAggregator;
use phub::coordinator::engine::{single_lane_fabrics, RoundTag, ShardEngine};
use phub::coordinator::optimizer::{NesterovSgd, Optimizer};
use phub::prop::Rng;

const CHUNK: usize = 8192;
const N_CHUNKS: usize = 64;
const WORKERS: usize = 8;
const ROUNDS: usize = 40;

/// Pre-refactor hot path: the raw absorb/mean/step loop a core ran before
/// the engine existed.
fn bench_raw(grads: &[Vec<f32>], params: &mut [f32], state: &mut [f32]) -> f64 {
    let opt = NesterovSgd {
        lr: 0.01,
        momentum: 0.9,
    };
    let mut aggs: Vec<ChunkAggregator> = (0..N_CHUNKS)
        .map(|_| ChunkAggregator::new(CHUNK, WORKERS))
        .collect();
    let t0 = Instant::now();
    for _ in 0..ROUNDS {
        for c in 0..N_CHUNKS {
            let off = c * CHUNK;
            for (w, g) in grads.iter().enumerate() {
                let done = aggs[c].absorb(w, &g[off..off + CHUNK]).unwrap();
                if done {
                    let mean = aggs[c].take_mean().unwrap();
                    opt.step(
                        &mut params[off..off + CHUNK],
                        &mut state[off..off + CHUNK],
                        mean,
                    );
                }
            }
        }
    }
    ROUNDS as f64 / t0.elapsed().as_secs_f64()
}

/// The same rounds through the engine: job lookup, epoch/round tag checks,
/// completion bookkeeping (pull masks off, so no reply traffic).
fn bench_engine(grads: &[Vec<f32>], init: &[f32]) -> f64 {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..N_CHUNKS)
        .map(|c| (c as u32, init[c * CHUNK..(c + 1) * CHUNK].to_vec()))
        .collect();
    // Pull is off in this bench, so the reply consumers just stay alive.
    let (txs, _rxs) = single_lane_fabrics(1, WORKERS, 16);
    eng.init_job(
        1,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        WORKERS,
        txs,
    );
    let t0 = Instant::now();
    for round in 0..ROUNDS as u64 {
        let tag = RoundTag::new(0, round);
        for c in 0..N_CHUNKS {
            let off = c * CHUNK;
            for (w, g) in grads.iter().enumerate() {
                eng.push(1, c as u32, w as u32, &g[off..off + CHUNK], false, tag)
                    .unwrap();
            }
        }
    }
    ROUNDS as f64 / t0.elapsed().as_secs_f64()
}

/// Recovery-path cost: rewind a half-pushed round across all chunks.
fn bench_rollback(grads: &[Vec<f32>], init: &[f32]) -> f64 {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..N_CHUNKS)
        .map(|c| (c as u32, init[c * CHUNK..(c + 1) * CHUNK].to_vec()))
        .collect();
    let (txs, _rxs) = single_lane_fabrics(2, WORKERS, 16);
    eng.init_job(
        2,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        WORKERS,
        txs,
    );
    let iters = 200usize;
    let t0 = Instant::now();
    for i in 0..iters as u64 {
        let tag = RoundTag::new(i as u32, 0);
        // Half the workers push every chunk, then the round is rewound.
        for c in 0..N_CHUNKS {
            let off = c * CHUNK;
            for (w, g) in grads.iter().enumerate().take(WORKERS / 2) {
                eng.push(2, c as u32, w as u32, &g[off..off + CHUNK], false, tag)
                    .unwrap();
            }
        }
        eng.rollback(2, i as u32 + 1).unwrap();
    }
    iters as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let elems = CHUNK * N_CHUNKS;
    println!(
        "== rollback bench: {N_CHUNKS} x {CHUNK}-elem chunks ({} MB), {WORKERS} workers ==",
        elems * 4 >> 20
    );
    let mut rng = Rng::new(7);
    let grads: Vec<Vec<f32>> = (0..WORKERS).map(|_| rng.vec_f32(elems, 1.0)).collect();
    let init = rng.vec_f32(elems, 1.0);

    let mut params = init.clone();
    let mut state = vec![0.0f32; elems];
    // Warmup + measure, interleaved to share cache state fairly.
    let _ = bench_raw(&grads, &mut params, &mut state);
    let raw = bench_raw(&grads, &mut params, &mut state);
    let _ = bench_engine(&grads, &init);
    let engine = bench_engine(&grads, &init);
    let rb = bench_rollback(&grads, &init);

    println!("  raw absorb+opt loop (pre-refactor):  {raw:>8.2} rounds/s");
    println!("  ShardEngine::push (epoch-tagged):    {engine:>8.2} rounds/s");
    println!(
        "  engine overhead:                     {:>+7.2}%",
        (raw / engine - 1.0) * 100.0
    );
    println!("  half-round rollback + re-push:       {rb:>8.2} rollbacks/s");
    println!("rollback bench OK");
}
