//! Observability-overhead bench (run via `cargo bench --bench trace`).
//!
//! Prices the flight recorder (ISSUE 9) on the end-to-end TCP training
//! path: rounds/s with tracing disabled, enabled (the default build and
//! runtime state), and enabled while a scraper thread hammers the
//! status endpoint's `/metrics` and `/trace` routes. The recorder's
//! contract is that recording is seqlock writes into preallocated slots
//! and scrapes never touch a data-plane lock, so "on" should sit within
//! a few percent of "off" and scraping should not collapse throughput.
//!
//! Results feed EXPERIMENTS.md section Perf; the last stdout line is the
//! JSON summary for BENCH_trace.json.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use phub::coordinator::server::ServerConfig;
use phub::coordinator::status::StatusServer;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};

const MODEL_ELEMS: u64 = 32 * 1024;
const CHUNK_ELEMS: u64 = 8 * 1024;
const N_CHUNKS: u64 = MODEL_ELEMS / CHUNK_ELEMS;
const WORKERS: u32 = 2;
const ROUNDS: usize = 300;

fn spec() -> JobSpec {
    JobSpec {
        model_elems: MODEL_ELEMS,
        chunk_elems: CHUNK_ELEMS,
        n_workers: WORKERS,
        lr: 0.01,
        momentum: 0.9,
    }
}

/// One blocking GET, body discarded — the scraper only exists to put
/// snapshot/seqlock read pressure on the recorder while training runs.
fn http_get(addr: SocketAddr, path: &str) {
    let Ok(mut s) = TcpStream::connect(addr) else {
        return;
    };
    if write!(s, "GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n").is_err() {
        return;
    }
    let _ = s.flush();
    let mut sink = Vec::new();
    let _ = s.read_to_end(&mut sink);
}

/// Rounds/s for one full 2-worker run with the recorder toggled as
/// given, optionally with a live scraper thread on the status endpoint.
fn run(trace_on: bool, scrape: bool) -> f64 {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    leader.server().set_tracing(trace_on);
    let status = scrape.then(|| StatusServer::bind("127.0.0.1:0", leader.metrics_arc()).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = status.as_ref().map(|st| {
        let addr = st.local_addr();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Acquire) {
                http_get(addr, "/metrics");
                http_get(addr, "/trace");
                scrapes += 1;
            }
            scrapes
        })
    });

    let addr = leader.local_addr();
    let t0 = Instant::now();
    let joins: Vec<_> = (0..WORKERS)
        .map(|_| {
            std::thread::spawn(move || {
                let s = spec();
                let n = s.model_elems as usize;
                let mut w = TcpWorker::connect(addr, 1, s).unwrap();
                let grad = vec![0.25f32; n];
                let mut model = vec![0.0f32; n];
                for _ in 0..ROUNDS {
                    w.push_pull_into(&grad, &mut model).unwrap();
                }
                w.bye();
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let rps = ROUNDS as f64 / t0.elapsed().as_secs_f64();

    stop.store(true, Ordering::Release);
    if let Some(t) = scraper {
        let scrapes = t.join().unwrap();
        assert!(scrapes > 0, "scraper never completed a request");
    }
    if let Some(st) = status {
        st.shutdown();
    }
    leader.server().set_tracing(true); // restore the process default
    rps
}

fn main() {
    println!(
        "== trace bench: {N_CHUNKS} x {CHUNK_ELEMS}-elem chunks, {WORKERS} workers, \
         {ROUNDS} rounds ==",
    );
    let _ = run(true, false); // warm-up
    let rps_off = run(false, false);
    let rps_on = run(true, false);
    let rps_scraped = run(true, true);
    let on_overhead_pct = (rps_off - rps_on) / rps_off * 100.0;
    println!("  tracing off:           {rps_off:>9.1} rounds/s");
    println!("  tracing on:            {rps_on:>9.1} rounds/s ({on_overhead_pct:+.2}% vs off)");
    println!("  tracing on + scraper:  {rps_scraped:>9.1} rounds/s");
    println!("trace bench OK");
    // Single-line JSON summary for BENCH_trace.json (keep last on
    // stdout).
    println!(
        "{{\"bench\":\"trace\",\"model_elems\":{MODEL_ELEMS},\"chunks\":{N_CHUNKS},\
         \"workers\":{WORKERS},\"rounds\":{ROUNDS},\"rps_off\":{rps_off:.1},\
         \"rps_on\":{rps_on:.1},\"rps_scraped\":{rps_scraped:.1},\
         \"on_overhead_pct\":{on_overhead_pct:.2}}}"
    );
}
