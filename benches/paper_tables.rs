//! Regenerates every TABLE in the paper's evaluation (run via
//! `cargo bench --bench paper_tables`).
//!
//! * Table 1 — framework scaling, ResNet-50, 56 Gbps, 1/2/4/8 nodes.
//! * Table 2 — minimum bisection bandwidth per PS configuration.
//! * Table 4 — PBox memory bandwidth: comm-only vs cached vs bypassed.
//! * Table 5 — datacenter cost model, throughput/$1000.
//!
//! Absolute numbers come from the simulated substrate (DESIGN.md section
//! 2); the claim is shape fidelity — orderings, ratios, crossovers —
//! recorded against the paper in EXPERIMENTS.md.

use phub::compute::Gpu;
use phub::config::{ClusterConfig, ExchangeConfig, NetConfig, PsConfig, Stack};
use phub::costmodel::{self, CostModel, Deployment};
use phub::dnn::Dnn;
use phub::memmodel::{self, ExchangeMemProfile};
use phub::sim;

fn table1() {
    println!("== Table 1: training throughput (samples/s), RN50, 56 Gbps ==");
    println!("paper (MXNet):     local 190 | 2n 187 | 4n 375 | 8n 688");
    let d = Dnn::by_abbrev("RN50").unwrap();
    let mut row_tcp = Vec::new();
    let mut row_phub = Vec::new();
    println!("  local (1 GPU, no PS): {:.0} samples/s", d.local_throughput());
    for n in [2usize, 4, 8] {
        let mx = ClusterConfig::paper_testbed()
            .with_ps(PsConfig::ColocatedSharded)
            .with_stack(Stack::MxnetTcp)
            .with_exchange(ExchangeConfig::mxnet())
            .with_workers(n);
        row_tcp.push(sim::simulate(&mx, &d, Gpu::Gtx1080Ti).throughput);
        let ph = ClusterConfig::paper_testbed().with_workers(n);
        row_phub.push(sim::simulate(&ph, &d, Gpu::Gtx1080Ti).throughput);
    }
    println!(
        "  measured MXNet TCP:  2n {:.0} | 4n {:.0} | 8n {:.0}",
        row_tcp[0], row_tcp[1], row_tcp[2]
    );
    println!(
        "  measured PHub PBox:  2n {:.0} | 4n {:.0} | 8n {:.0}",
        row_phub[0], row_phub[1], row_phub[2]
    );
    let ideal8 = 8.0 * d.local_throughput();
    println!(
        "  scaling efficiency @8: MXNet {:.0}%, PHub {:.0}% (ideal {ideal8:.0})",
        100.0 * row_tcp[2] / ideal8,
        100.0 * row_phub[2] / ideal8
    );
}

fn table2() {
    println!("\n== Table 2: min bandwidth (Gbps) to hide communication, 8 workers ==");
    println!(
        "{:<8} {:>8} {:>8} {:>8} {:>8}   paper (CC,CS,NCC,NCS)",
        "network", "CC", "CS", "NCC", "NCS"
    );
    let paper: &[(&str, [f64; 4])] = &[
        ("RN269", [122.0, 31.0, 140.0, 17.0]),
        ("I3", [44.0, 11.0, 50.0, 6.0]),
        ("GN", [40.0, 10.0, 46.0, 6.0]),
        ("AN", [1232.0, 308.0, 1408.0, 176.0]),
    ];
    for (abbrev, p) in paper {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let r = costmodel::table2_row(&d, 8);
        println!(
            "{:<8} {:>8.0} {:>8.0} {:>8.0} {:>8.0}   ({:.0},{:.0},{:.0},{:.0})",
            abbrev, r[0], r[1], r[2], r[3], p[0], p[1], p[2], p[3]
        );
    }
}

fn table4() {
    println!("\n== Table 4: PBox memory bandwidth (GB/s) & throughput, VGG x8 ==");
    println!("paper: off 77.5/72.08 | cached 83.5/71.6 | bypass 119.7/40.48");
    let vgg = 505.0 * 1024.0 * 1024.0;
    let dram = 120e9;
    let net_bound = 72.08; // network-side exchange bound, exchanges/s
    for (name, prof) in [
        ("off", ExchangeMemProfile::off()),
        ("cached", ExchangeMemProfile::cached()),
        ("bypass", ExchangeMemProfile::bypass()),
    ] {
        let rate = memmodel::exchange_rate(prof, vgg, net_bound, dram);
        let bw = memmodel::mem_bw_used(prof, vgg, rate) / 1e9;
        println!("  {name:<7} mem bw {bw:6.1} GB/s  throughput {rate:6.2} exchanges/s");
    }
}

fn table5() {
    println!("\n== Table 5: throughput per $1000 (RN50) ==");
    println!("paper (future GPUs): 100Gb 46.11 | PHub 1:1 55.19 | 2:1 57.71 | 3:1 59.03");
    let d = Dnn::by_abbrev("RN50").unwrap();
    // Baseline: sharded MXNet IB on a 40G-class network; PHub on 10G-class
    // (the paper's stand-ins for 100/25 GbE), V100-class GPUs.
    let base = ClusterConfig::paper_testbed()
        .with_ps(PsConfig::ColocatedSharded)
        .with_stack(Stack::MxnetIb)
        .with_net(NetConfig {
            link_gbps: 40.0,
            ..NetConfig::infiniband_56g()
        })
        .with_exchange(ExchangeConfig::mxnet());
    let phub = ClusterConfig::paper_testbed().with_net(NetConfig::cloud_10g());
    for (label, gpu, gpu_price) in [
        ("future GPUs", Gpu::V100, 699.0),
        ("spendy (V100 $8k)", Gpu::V100, 8000.0),
        ("cheap-CPU workers", Gpu::V100, 699.0),
    ] {
        let tp_base = sim::simulate(&base, &d, gpu).throughput / 8.0;
        let tp_phub = sim::simulate(&phub, &d, gpu).throughput / 8.0 * 0.98; // +2% cross-rack
        let mut m = CostModel::paper();
        m.prices.gpu = gpu_price;
        if label.starts_with("cheap") {
            m.prices.worker = 2000.0; // E5-2603 v4 class barebone
        }
        let b = m.throughput_per_kilodollar(&Deployment::baseline_100g(), tp_base);
        print!("  {label:<20} baseline {b:6.2}");
        for o in [1.0, 2.0, 3.0] {
            let v = m.throughput_per_kilodollar(&Deployment::phub_25g(o), tp_phub);
            print!(" | {o:.0}:1 {v:6.2} ({:+.0}%)", (v / b - 1.0) * 100.0);
        }
        println!();
    }
}

fn main() {
    let t0 = std::time::Instant::now();
    table1();
    table2();
    table4();
    table5();
    println!("\n[paper_tables done in {:.1}s]", t0.elapsed().as_secs_f64());
}
